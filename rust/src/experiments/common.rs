//! Shared experiment infrastructure: task builders and the generic
//! "train task X with algorithm Y" runner used by every table/figure
//! driver — all thin layers over the [`crate::api::Session`] front door
//! (the compressor zoo itself lives in [`crate::api::CompressorSpec`]).
//!
//! Scale note: the paper ran 16 V100s for 90-300 epochs; this repo runs
//! synthetic stand-ins on CPU (see DESIGN.md). Experiment defaults are
//! sized for a single-core box; every knob (workers, rounds, seeds) is a
//! config key, so `workers=16 rounds=600 seeds=3` reproduces the full
//! protocol when given the hardware.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::api::{CompressorSpec, ModelSpec, Session, SourceFactory};
use crate::config::Config;
use crate::coordinator::{BatchSpec, LrSchedule, PjrtEvaluator, PjrtWorker, TrainResult};
use crate::data::{shard_iid, CifarLike, MarkovText};
use crate::runtime::{init_params, lit_f32, lit_i32, Runtime};

/// The two deep-learning tasks of §5.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Classifier,
    Lm,
    Transformer,
}

impl Task {
    pub fn model_name(self) -> &'static str {
        match self {
            Task::Classifier => "classifier",
            Task::Lm => "lm",
            Task::Transformer => "transformer",
        }
    }
}

/// Resolved experiment geometry from config.
pub struct Setup {
    pub artifact_dir: String,
    pub workers: usize,
    pub rounds: usize,
    pub seeds: Vec<u64>,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    pub eval_every: usize,
    pub out_dir: String,
}

pub fn setup(cfg: &Config, default_rounds: usize, default_lr: f32) -> Setup {
    let seed_count = cfg.usize_or("seeds", 1);
    Setup {
        artifact_dir: cfg.str_or("artifacts", "artifacts").to_string(),
        workers: cfg.usize_or("workers", 8),
        rounds: cfg.usize_or("rounds", default_rounds),
        seeds: (0..seed_count as u64).collect(),
        lr: cfg.f32_or("lr", default_lr),
        momentum: cfg.f32_or("momentum", 0.9),
        weight_decay: cfg.f32_or("weight_decay", 1e-4),
        eval_every: cfg.usize_or("eval_every", 25),
        out_dir: cfg.str_or("out_dir", "results").to_string(),
    }
}

/// Parameter layout (shapes in flattening order) for a model.
pub fn model_layout(rt: &Runtime, model: &str) -> Result<Vec<Vec<usize>>> {
    let meta = rt
        .meta(&format!("{model}_train_step"))
        .ok_or_else(|| anyhow!("{model}: no train_step artifact"))?;
    Ok(meta.params.iter().map(|p| p.shape.clone()).collect())
}

/// The display names used in the paper's tables (by experiment id).
pub fn paper_name(algo: &str) -> &'static str {
    CompressorSpec::parse(algo).map(|s| s.paper_name()).unwrap_or("?")
}

/// Output of one (task, algorithm, seed) run.
pub struct RunOutput {
    pub result: TrainResult,
    /// Final test metric: (loss, accuracy) — accuracy 0 for LM tasks.
    pub test: (f64, f64),
}

/// Train `task` with `algo` for one seed; the full L3-over-PJRT path.
#[allow(clippy::too_many_arguments)]
pub fn run_task(
    task: Task,
    algo: &str,
    s: &Setup,
    beta: f64,
    eps: f64,
    seed: u64,
    cfg: &Config,
) -> Result<RunOutput> {
    let spec = CompressorSpec::parse(algo)?;
    let mut session = task_session(task, &spec, s, beta, eps, seed, cfg)?;
    session.run(s.rounds)?;
    let result = session.finish();
    let test = result
        .evals
        .last()
        .map(|&(_, l, a)| (l, a))
        .unwrap_or((f64::NAN, 0.0));
    Ok(RunOutput { result, test })
}

/// Build a ready-to-run [`Session`] for one of the paper's PJRT-backed
/// tasks: manifest-derived model layout and init, per-rank PJRT worker
/// factories over sharded synthetic data, the paper's warmup + /10
/// milestone schedule, and an eval hook bound to the task's test split.
#[allow(clippy::too_many_arguments)]
pub fn task_session(
    task: Task,
    spec: &CompressorSpec,
    s: &Setup,
    beta: f64,
    eps: f64,
    seed: u64,
    cfg: &Config,
) -> Result<Session> {
    let model = task.model_name();
    let rt = Runtime::open(&s.artifact_dir)?;
    let layout = model_layout(&rt, model)?;
    let meta = rt.meta(&format!("{model}_train_step")).unwrap().clone();

    // -- data ----------------------------------------------------------
    let n = s.workers;
    let factories: Vec<SourceFactory> =
        match task {
            Task::Classifier => {
                let train = cfg.usize_or("train_examples", 4096);
                let test = cfg.usize_or("test_examples", 1024);
                let margin = cfg.f32_or("margin", 1.2);
                let data = Arc::new(CifarLike::generate(train, test, margin, 1000 + seed));
                let shards = shard_iid(data.train_count(), n, 2000 + seed);
                let batch = meta.extra_usize("batch").unwrap_or(32);
                let dir = s.artifact_dir.clone();
                shards
                    .into_iter()
                    .enumerate()
                    .map(|(i, indices)| {
                        let data = Arc::clone(&data);
                        let dir = dir.clone();
                        let f: Box<dyn FnOnce() -> Box<dyn crate::coordinator::GradientSource> + Send> =
                            Box::new(move || {
                                Box::new(
                                    PjrtWorker::new(
                                        &dir,
                                        "classifier",
                                        BatchSpec::Classifier { data, indices, batch },
                                        seed * 1000 + i as u64,
                                    )
                                    .expect("pjrt worker"),
                                )
                            });
                        f
                    })
                    .collect()
            }
            Task::Lm | Task::Transformer => {
                let vocab = meta.extra_usize("vocab").unwrap_or(64);
                let corpus_len = cfg.usize_or("corpus_len", 200_000);
                let text = Arc::new(MarkovText::generate(
                    vocab,
                    corpus_len,
                    corpus_len / 10,
                    0.08,
                    3000 + seed,
                ));
                let batch = meta.extra_usize("batch").unwrap_or(16);
                let seq = meta.extra_usize("seq").unwrap_or(30);
                let dir = s.artifact_dir.clone();
                let shard_len = text.train.len() / n;
                (0..n)
                    .map(|i| {
                        let shard: Arc<Vec<u32>> = Arc::new(
                            text.train[i * shard_len..(i + 1) * shard_len].to_vec(),
                        );
                        let dir = dir.clone();
                        let model = model.to_string();
                        let f: Box<dyn FnOnce() -> Box<dyn crate::coordinator::GradientSource> + Send> =
                            Box::new(move || {
                                Box::new(
                                    PjrtWorker::new(
                                        &dir,
                                        &model,
                                        BatchSpec::Lm { tokens: shard, batch, seq },
                                        seed * 1000 + i as u64,
                                    )
                                    .expect("pjrt worker"),
                                )
                            });
                        f
                    })
                    .collect()
            }
        };

    // -- eval hook -------------------------------------------------------
    let mut evaluator = PjrtEvaluator::new(&s.artifact_dir, model)?;
    let mut eval_data_provider = make_eval_provider(task, &meta, cfg, seed)?;
    let eval_hook = move |params: &[f32]| -> (f64, f64) {
        let data = eval_data_provider();
        match evaluator.eval(params, data) {
            Ok(outs) => (
                outs.first().copied().unwrap_or(f32::NAN) as f64,
                outs.get(1).copied().unwrap_or(0.0) as f64,
            ),
            Err(e) => {
                eprintln!("eval failed: {e}");
                (f64::NAN, 0.0)
            }
        }
    };

    // -- the session ----------------------------------------------------
    let init: Vec<f32> = init_params(&meta.params, 42 + seed).concat();
    let warmup = cfg.usize_or("warmup_rounds", s.rounds / 20);
    Session::builder()
        .world(n)
        .model(ModelSpec::with_params(init, layout))
        .sources(factories)
        .compressor(spec.clone())
        .beta(beta)
        .eps(eps)
        .seed(77 + seed)
        .schedule(LrSchedule {
            base: s.lr,
            warmup_rounds: warmup,
            milestones: vec![(s.rounds / 2, 0.1), (s.rounds * 5 / 6, 0.1)],
        })
        .momentum(s.momentum)
        .weight_decay(s.weight_decay)
        .eval_every(s.eval_every)
        .eval_hook(Box::new(eval_hook))
        .build()
}

/// Builds a closure producing fresh eval-batch literals each call.
fn make_eval_provider(
    task: Task,
    meta: &crate::runtime::ArtifactMeta,
    cfg: &Config,
    seed: u64,
) -> Result<Box<dyn FnMut() -> Vec<xla::Literal>>> {
    match task {
        Task::Classifier => {
            let test = cfg.usize_or("test_examples", 1024);
            let train = cfg.usize_or("train_examples", 4096);
            let margin = cfg.f32_or("margin", 1.2);
            let data = CifarLike::generate(train, test, margin, 1000 + seed);
            let eval_batch = 256;
            let mut cursor = 0usize;
            Ok(Box::new(move || {
                let (x, y) = data.test_batch(cursor, eval_batch);
                cursor = (cursor + eval_batch) % data.test_y.len().max(1);
                vec![
                    lit_f32(&x, &[eval_batch, data.dim]).unwrap(),
                    lit_f32(&y, &[eval_batch, data.classes]).unwrap(),
                ]
            }))
        }
        Task::Lm | Task::Transformer => {
            let vocab = meta.extra_usize("vocab").unwrap_or(64);
            let corpus_len = cfg.usize_or("corpus_len", 200_000);
            let text = MarkovText::generate(
                vocab,
                corpus_len,
                corpus_len / 10,
                0.08,
                3000 + seed,
            );
            let batch = meta.extra_usize("batch").unwrap_or(16);
            let seq = meta.extra_usize("seq").unwrap_or(30);
            let mut rng = crate::util::Rng::new(9000 + seed);
            Ok(Box::new(move || {
                let w = MarkovText::batch_windows(&text.test, batch, seq, &mut rng);
                vec![lit_i32(&w, &[batch, seq + 1]).unwrap()]
            }))
        }
    }
}
