//! Experiment drivers: one module per paper table/figure (DESIGN.md §4).

pub mod ablation;
pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3_4;
pub mod fig5;
pub mod fig6;
pub mod registry;
pub mod table2_3;
pub mod train_cmd;

pub use registry::{list, run};
