//! `repro trace` — a short traced training run: every phase span
//! (encode / reduce / drain / decode, per block and rank) lands in the
//! telemetry journal and is written out as a Chrome `chrome://tracing`
//! trace, so the streamed pipeline's encode-over-wire overlap is visible
//! as overlapping bars instead of a number in a table.
//!
//!   repro trace out=trace.json pipeline=streamed rounds=12
//!
//! Defaults differ from `net-bench` where tracing wants them to: the
//! transport is `channel` (deterministic, no sockets needed to see the
//! schedule) and the pipeline is `streamed` (the overlap is the point).
//! All `net-bench` knobs are accepted (validated against
//! `api::keys::TRACE`), plus:
//!
//! | key | default | meaning |
//! |-----|---------|---------|
//! | `out` | `trace.json` | trace output path (alias of `telemetry.trace_path`, which wins if both are set) |
//! | `serve_ms` | 0 | keep the Prometheus endpoint up this long after the run (needs `telemetry.listen`) |

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::api::{CompressorSpec, ModelSpec, Session};
use crate::config::Config;
use crate::telemetry::{self, TelemetrySink};

use super::net_driver::{fault_spec, pipeline_knob, quad_factories, staged_algo, transport_knob};

pub fn run(cfg: &Config) -> Result<()> {
    let n = cfg.parsed_or("workers", 4usize)?;
    let d = cfg.parsed_or("d", 1usize << 14)?;
    let rounds = cfg.parsed_or("rounds", 12usize)?;
    let lr = cfg.parsed_or("lr", 0.2f32)?;
    let seed = cfg.parsed_or("seed", 100u64)?;
    let algo = staged_algo(cfg)?;
    let pipeline = pipeline_knob(cfg, "streamed")?;
    let (backend, label) = transport_knob(cfg, "channel", algo)?;
    let out = cfg
        .get("telemetry.trace_path")
        .unwrap_or_else(|| cfg.str_or("out", "trace.json"))
        .to_string();
    let faults = fault_spec(cfg, seed)?;

    let mut builder = Session::builder()
        .world(n)
        .model(ModelSpec::flat(d))
        .sources(quad_factories(n, d, seed, 0.01))
        .compressor(CompressorSpec::parse("intsgd_random8")?)
        .seed(seed ^ 0x5EED)
        .lr(lr)
        .backend(backend)
        .pipeline(pipeline)
        .net_timeout(Duration::from_millis(cfg.parsed_or(
            "net.timeout_ms",
            crate::net::default_io_timeout().as_millis() as u64,
        )?))
        .net_retries(cfg.parsed_or("net.retries", 8usize)?)
        .trace_path(out.clone());
    if let Some(f) = faults {
        builder = builder.faults(f);
    }
    if let Some(addr) = cfg.get("telemetry.listen") {
        builder = builder.metrics_listen(addr);
    }
    let mut session = builder.build()?;

    println!(
        "trace: {} over {label} ({algo:?}, {pipeline:?}), n = {n}, d = {d}, \
         {rounds} rounds -> {out}",
        session.algorithm(),
    );
    if let Some(addr) = session.metrics_addr() {
        println!("  metrics: http://{addr}/metrics");
    }
    let mut sink = TelemetrySink::new();
    session.run_observed(rounds, &mut sink)?;
    // write before any serve window so the file exists while scraping
    session.write_trace()?;
    println!(
        "  {} phase spans journaled; wire time measured {:.3} ms \
         (open the trace in chrome://tracing or ui.perfetto.dev)",
        telemetry::journal::snapshot().len(),
        sink.measured() * 1e3,
    );

    let serve_ms = cfg.parsed_or("serve_ms", 0u64)?;
    if serve_ms > 0 {
        if session.metrics_addr().is_none() {
            return Err(anyhow!("serve_ms needs telemetry.listen=<addr>"));
        }
        println!("  serving metrics for {serve_ms} ms ...");
        std::thread::sleep(Duration::from_millis(serve_ms));
    }
    session.finish();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn trace_cmd_writes_a_parseable_trace_with_phase_events() {
        let out = std::env::temp_dir()
            .join(format!("intsgd_trace_cmd_{}.json", std::process::id()));
        let mut cfg = Config::new();
        for kv in ["workers=3", "d=768", "rounds=6", "serve_ms=0"] {
            cfg.set_kv(kv).unwrap();
        }
        cfg.set_kv(&format!("out={}", out.display())).unwrap();
        run(&cfg).expect("trace run");

        let text = std::fs::read_to_string(&out).expect("trace written");
        let json = Json::parse(&text).expect("valid JSON");
        let events = json
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        // the journal is process-global, so other tests may contribute
        // events too — assert presence, not exact counts
        let has = |name: &str| {
            events.iter().any(|e| {
                e.get("name").and_then(Json::as_str).is_some_and(|s| s.starts_with(name))
            })
        };
        assert!(has("round"), "no round spans in trace");
        assert!(has("reduce"), "no reduce spans in trace");
        assert!(has("encode"), "no encode spans in trace");
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn serve_without_listen_is_a_typed_error() {
        let out = std::env::temp_dir()
            .join(format!("intsgd_trace_cmd_err_{}.json", std::process::id()));
        let mut cfg = Config::new();
        for kv in ["workers=2", "d=64", "rounds=2", "serve_ms=5"] {
            cfg.set_kv(kv).unwrap();
        }
        cfg.set_kv(&format!("out={}", out.display())).unwrap();
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains("telemetry.listen"), "{err}");
        let _ = std::fs::remove_file(&out);
    }
}
