//! PJRT-backed gradient workers: each worker thread owns a PJRT CPU client
//! with the AOT train-step executable and computes gradients on its local
//! shard — the L3 <-> L2 boundary of the stack.
//!
//! Construction happens inside the worker thread (`WorkerPool::spawn`
//! factories): PJRT clients are Rc-backed and must not cross threads.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::data::{CifarLike, MarkovText};
use crate::runtime::{lit_f32, lit_i32, Dtype, Runtime};
use crate::util::Rng;

use super::GradientSource;

/// Which minibatch stream feeds the train step.
pub enum BatchSpec {
    /// Classifier: (x[B, D], one-hot y[B, C]) sampled from shard indices.
    Classifier { data: Arc<CifarLike>, indices: Vec<usize>, batch: usize },
    /// LM: token windows [B, T+1] sampled from a shard of the corpus.
    Lm { tokens: Arc<Vec<u32>>, batch: usize, seq: usize },
}

/// A worker executing `<model>_train_step` through PJRT.
pub struct PjrtWorker {
    rt: Runtime,
    exe_name: String,
    batch: BatchSpec,
    rng: Rng,
    /// Parameter array boundaries (numels in artifact order).
    param_numels: Vec<usize>,
    param_shapes: Vec<Vec<usize>>,
    grad_dim: usize,
}

impl PjrtWorker {
    /// Build inside the worker thread. `model` is "classifier" | "lm" |
    /// "transformer".
    pub fn new(artifact_dir: &str, model: &str, batch: BatchSpec, seed: u64) -> Result<Self> {
        let mut rt = Runtime::open(artifact_dir)?;
        let exe_name = format!("{model}_train_step");
        rt.load(&exe_name)?; // compile now, fail fast
        let meta = rt.meta(&exe_name).ok_or_else(|| anyhow!("missing meta"))?;
        let param_numels: Vec<usize> = meta.params.iter().map(|p| p.numel()).collect();
        let param_shapes: Vec<Vec<usize>> =
            meta.params.iter().map(|p| p.shape.clone()).collect();
        let grad_dim = meta.grad_dim;
        Ok(PjrtWorker {
            rt,
            exe_name,
            batch,
            rng: Rng::new(seed),
            param_numels,
            param_shapes,
            grad_dim,
        })
    }

    fn param_literals(&self, flat: &[f32]) -> Result<Vec<xla::Literal>> {
        split_params(flat, &self.param_numels, &self.param_shapes)
    }

    fn batch_literals(&mut self) -> Result<Vec<xla::Literal>> {
        match &self.batch {
            BatchSpec::Classifier { data, indices, batch } => {
                let idx: Vec<usize> = (0..*batch)
                    .map(|_| indices[self.rng.usize_below(indices.len())])
                    .collect();
                let (x, y) = data.batch(&idx);
                Ok(vec![
                    lit_f32(&x, &[*batch, data.dim])?,
                    lit_f32(&y, &[*batch, data.classes])?,
                ])
            }
            BatchSpec::Lm { tokens, batch, seq } => {
                let w = MarkovText::batch_windows(tokens, *batch, *seq, &mut self.rng);
                Ok(vec![lit_i32(&w, &[*batch, *seq + 1])?])
            }
        }
    }
}

/// Split a flat parameter vector into per-array literals.
pub fn split_params(
    flat: &[f32],
    numels: &[usize],
    shapes: &[Vec<usize>],
) -> Result<Vec<xla::Literal>> {
    let total: usize = numels.iter().sum();
    if flat.len() != total {
        return Err(anyhow!("flat params {} != manifest total {total}", flat.len()));
    }
    let mut out = Vec::with_capacity(numels.len());
    let mut off = 0;
    for (numel, shape) in numels.iter().zip(shapes) {
        out.push(lit_f32(&flat[off..off + numel], shape)?);
        off += numel;
    }
    Ok(out)
}

impl GradientSource for PjrtWorker {
    fn dim(&self) -> usize {
        self.grad_dim
    }

    fn grad(&mut self, params: &[f32], _round: usize) -> (f32, Vec<f32>) {
        let mut run = || -> Result<(f32, Vec<f32>)> {
            let mut inputs = self.param_literals(params)?;
            inputs.extend(self.batch_literals()?);
            let exe = self.rt.load(&self.exe_name)?;
            let outs = exe.run(&inputs)?;
            let loss = outs[0].get_first_element::<f32>()?;
            let mut grad = Vec::with_capacity(self.grad_dim);
            for o in &outs[1..] {
                grad.extend(o.to_vec::<f32>()?);
            }
            debug_assert_eq!(grad.len(), self.grad_dim);
            Ok((loss, grad))
        };
        run().expect("pjrt train step")
    }
}

/// Leader-side evaluation through the `<model>_eval_step` artifact.
pub struct PjrtEvaluator {
    rt: Runtime,
    exe_name: String,
    param_numels: Vec<usize>,
    param_shapes: Vec<Vec<usize>>,
    data_inputs: Vec<(Vec<usize>, Dtype)>,
}

impl PjrtEvaluator {
    pub fn new(artifact_dir: &str, model: &str) -> Result<Self> {
        let mut rt = Runtime::open(artifact_dir)?;
        let exe_name = format!("{model}_eval_step");
        rt.load(&exe_name)?;
        let train_meta = rt
            .meta(&format!("{model}_train_step"))
            .ok_or_else(|| anyhow!("missing train meta"))?;
        let param_numels: Vec<usize> =
            train_meta.params.iter().map(|p| p.numel()).collect();
        let param_shapes: Vec<Vec<usize>> =
            train_meta.params.iter().map(|p| p.shape.clone()).collect();
        let eval_meta = rt.meta(&exe_name).unwrap();
        let data_inputs: Vec<(Vec<usize>, Dtype)> = eval_meta.inputs
            [param_numels.len()..]
            .iter()
            .map(|i| (i.shape.clone(), i.dtype))
            .collect();
        Ok(PjrtEvaluator { rt, exe_name, param_numels, param_shapes, data_inputs })
    }

    /// Expected data-input shapes (after the params).
    pub fn data_shapes(&self) -> &[(Vec<usize>, Dtype)] {
        &self.data_inputs
    }

    /// Run eval; returns the raw outputs as f32 scalars.
    pub fn eval(&mut self, params: &[f32], data: Vec<xla::Literal>) -> Result<Vec<f32>> {
        let mut inputs = split_params(params, &self.param_numels, &self.param_shapes)?;
        inputs.extend(data);
        let exe = self.rt.load(&self.exe_name)?;
        let outs = exe.run(&inputs)?;
        outs.iter()
            .map(|o| Ok(o.get_first_element::<f32>()?))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_params_boundaries() {
        let flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let lits = split_params(&flat, &[4, 6], &[vec![2, 2], vec![6]]).unwrap();
        assert_eq!(lits.len(), 2);
        assert_eq!(lits[0].to_vec::<f32>().unwrap(), vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(lits[1].element_count(), 6);
    }

    #[test]
    fn split_params_rejects_mismatch() {
        assert!(split_params(&[0.0; 5], &[4], &[vec![4]]).is_err());
    }
}
