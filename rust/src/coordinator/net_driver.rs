//! `repro net-bench` — full IntSGD training rounds over a real transport.
//!
//! The multi-thread-loopback driver: n worker threads compute gradients
//! and encode (as in every other driver), but the integer aggregation
//! leaves the leader's address space — a `net::TransportReducer` runs the
//! staged ring (or halving) all-reduce over loopback TCP sockets (or
//! in-process channels), moving the same framed bytes a multi-node
//! deployment would. Afterwards the driver replays a few standalone
//! rounds to print `netsim`'s **measured-vs-modeled** breakdown: real
//! socket wall-clock next to the alpha-beta cost of the identical wire
//! schedule ([`Network::round_breakdown_measured`]) — the first time the
//! cost model is validated against actual wire time instead of standing
//! unfalsifiable.
//!
//!   repro net-bench workers=4 d=65536 rounds=20 transport=tcp algo=ring

use anyhow::{anyhow, Result};

use crate::compress::intsgd::{IntSgd, Rounding, WireInt};
use crate::compress::RoundEngine;
use crate::config::Config;
use crate::net::{StagedAlgo, Transport, TransportReducer};
use crate::netsim::Network;
use crate::scaling::MovingAverageRule;
use crate::util::Rng;

use super::{
    BlockInfo, Coordinator, GradientSource, LrSchedule, RoundCtx, TrainConfig, WorkerPool,
};

/// Synthetic heterogeneous quadratic: f_i(x) = 0.5 ||x - c_i||^2 with
/// optional gradient noise. Cheap enough that the round cost is
/// dominated by what this driver exists to measure — the wire. Shared by
/// the coordinator tests and the net parity/loopback suites (one oracle,
/// not five copies).
pub struct Quad {
    center: Vec<f32>,
    noise: f32,
    rng: Rng,
}

impl GradientSource for Quad {
    fn dim(&self) -> usize {
        self.center.len()
    }

    fn grad(&mut self, params: &[f32], _round: usize) -> (f32, Vec<f32>) {
        let g: Vec<f32> = params
            .iter()
            .zip(&self.center)
            .map(|(&x, &c)| x - c + self.noise * self.rng.normal_f32())
            .collect();
        let loss = 0.5
            * params
                .iter()
                .zip(&self.center)
                .map(|(&x, &c)| (x - c) * (x - c))
                .sum::<f32>();
        (loss, g)
    }
}

/// A worker pool of [`Quad`] oracles: rank i draws its center from
/// `Rng::new(seed + i)` (so callers can recompute the optimum), then
/// keeps the stream for its gradient noise.
pub fn quad_pool(n: usize, d: usize, seed: u64, noise: f32) -> WorkerPool {
    let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> = (0..n)
        .map(|i| {
            let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                Box::new(move || {
                    let mut rng = Rng::new(seed + i as u64);
                    Box::new(Quad {
                        center: rng.normal_vec(d, 1.0),
                        noise,
                        rng,
                    }) as Box<dyn GradientSource>
                });
            f
        })
        .collect();
    WorkerPool::spawn(factories)
}

fn intsgd_engine(n: usize, seed: u64) -> RoundEngine {
    RoundEngine::new(Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        n,
        seed,
    )))
}

/// Train + measure over a concrete transport (monomorphized per mesh).
fn drive<T: Transport>(
    red: &mut TransportReducer<T>,
    label: &str,
    n: usize,
    d: usize,
    rounds: usize,
    lr: f32,
    seed: u64,
) -> Result<()> {
    let net = Network::tcp_loopback();
    let mut pool = quad_pool(n, d, seed, 0.01);
    let mut coord = Coordinator::new(vec![0.0; d], vec![d], net.clone());
    let mut engine = intsgd_engine(n, seed ^ 0x5EED);
    let cfg = TrainConfig {
        rounds,
        schedule: LrSchedule::constant(lr),
        ..Default::default()
    };

    println!(
        "net-bench: intsgd_random_int8 over {label} ({:?}), n = {n}, d = {d}, {rounds} rounds",
        red.algo()
    );
    let res = coord.train_over(&mut pool, &mut engine, &mut *red, &cfg, None);
    let first = res.records.first().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let last = res.records.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let modeled_int: f64 =
        res.records.iter().skip(1).map(|r| r.comm_seconds).sum();
    let measured = red.take_wire_seconds();
    println!(
        "  train loss {first:.4} -> {last:.4}; {} staged collectives \
         (last wire {:?})",
        red.calls(),
        red.last_wire(),
    );
    println!(
        "  integer-round wire time: measured {:.3} ms, modeled {:.3} ms \
         (ratio {:.2})",
        measured * 1e3,
        modeled_int * 1e3,
        measured / modeled_int.max(1e-12)
    );
    if last.is_nan() || last >= first {
        return Err(anyhow!(
            "training over {label} made no progress: {first} -> {last}"
        ));
    }

    // standalone rounds: the per-round measured-vs-modeled breakdown
    println!("\n  round breakdown (seconds measured on this machine):");
    println!(
        "  {:<8} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "round", "encode", "reduce", "decode", "comm_model", "comm_measured"
    );
    let ctx = RoundCtx {
        round: rounds.max(1),
        n,
        d,
        lr,
        step_norm_sq: 1e-4,
        blocks: vec![BlockInfo { dim: d, step_norm_sq: 1e-4 }],
    };
    for k in 0..3 {
        let (grads, _, _) = pool.compute_round(&coord.params, rounds + k);
        let result = engine.round_parallel_over(&mut pool, &mut *red, &grads, &ctx);
        let b = net.round_breakdown_measured(&result, n, red.take_wire_seconds());
        println!(
            "  {:<8} {:>12.6} {:>12.6} {:>12.6} {:>14.6} {:>14.6}",
            k, b.encode, b.reduce, b.decode, b.comm_model, b.comm_measured
        );
        engine.reclaim(result);
    }
    pool.shutdown();
    Ok(())
}

pub fn run(cfg: &Config) -> Result<()> {
    let n = cfg.usize_or("workers", 4);
    let d = cfg.usize_or("d", 1 << 16);
    let rounds = cfg.usize_or("rounds", 20);
    let lr = cfg.f32_or("lr", 0.2);
    let seed = cfg.u64_or("seed", 100);
    let algo = match cfg.str_or("algo", "ring") {
        "ring" => StagedAlgo::Ring,
        "halving" => StagedAlgo::Halving,
        other => return Err(anyhow!("unknown staged algo {other:?} (ring|halving)")),
    };
    match cfg.str_or("transport", "tcp") {
        "tcp" => {
            let mut red = TransportReducer::tcp_loopback(n, algo)?;
            drive(&mut red, "tcp-loopback", n, d, rounds, lr, seed)
        }
        "channel" => {
            let mut red = TransportReducer::channel_mesh(n, algo);
            drive(&mut red, "in-proc channels", n, d, rounds, lr, seed)
        }
        other => Err(anyhow!("unknown transport {other:?} (tcp|channel)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn net_bench_runs_end_to_end_over_channels() {
        // the in-proc transport keeps this tier-1 fast & deterministic;
        // the TCP path is covered by tests/net_loopback.rs
        let mut cfg = Config::new();
        for kv in ["transport=channel", "workers=3", "d=512", "rounds=8"] {
            cfg.set_kv(kv).unwrap();
        }
        run(&cfg).expect("channel net-bench");
    }

    #[test]
    fn rejects_unknown_knobs() {
        let mut cfg = Config::new();
        cfg.set_kv("transport=carrier-pigeon").unwrap();
        assert!(run(&cfg).is_err());
        let mut cfg = Config::new();
        cfg.set_kv("algo=butterfly").unwrap();
        assert!(run(&cfg).is_err());
    }
}
