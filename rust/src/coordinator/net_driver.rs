//! `repro net-bench` — full IntSGD training rounds over a real transport,
//! wired through the [`crate::api::Session`] front door.
//!
//! The multi-thread-loopback driver: n worker threads compute gradients
//! and encode (as in every other driver), but the integer aggregation
//! leaves the leader's address space — the session's transport backend
//! runs the staged ring (or halving) all-reduce over loopback TCP sockets
//! (or in-process channels), moving the same framed bytes a multi-node
//! deployment would. A [`RoundObserver`] streams `netsim`'s
//! **measured-vs-modeled** breakdown round by round (real socket
//! wall-clock next to the alpha-beta cost of the identical wire schedule,
//! plus the fault/retry account when chaos is injected) — no result-vec
//! post-processing.
//!
//!   repro net-bench workers=4 d=65536 rounds=20 transport=tcp algo=ring
//!
//! Knobs (`key=value`; validated against `api::keys::NET`, so a typo is
//! an error with a suggestion, and malformed numbers fail parsing instead
//! of silently becoming defaults):
//!
//! | key | default | meaning |
//! |-----|---------|---------|
//! | `workers`, `d`, `rounds`, `lr`, `seed` | 4, 2^16, 20, 0.2, 100 | job shape |
//! | `transport` | `tcp` | `tcp` or `channel` |
//! | `algo` | `ring` | `ring`, `halving` (pow2 world), or `two-level` (hierarchical leader fold; see `hierarchy.group_size`) |
//! | `hierarchy.group_size` | 4 | ranks per "node" for `algo=two-level`; must divide `workers` |
//! | `pipeline` | `barrier` | `barrier` or `streamed` (double-buffered block pipeline: encode block k+1 while block k is on the wire — bit-identical) |
//! | `net.timeout_ms` | 30000 (env `INTSGD_NET_TIMEOUT_MS`) | blocking-IO deadline; expiry is a typed `NetError::Timeout`, not a generic error |
//! | `net.retries` | 8 | retried attempts per collective before giving up |
//! | `fault.drop` / `fault.dup` / `fault.corrupt` / `fault.truncate` / `fault.delay` | 0 | per-frame fault probabilities (seeded, deterministic) |
//! | `fault.seed` | `seed` | fault-stream seed |
//! | `fault.kill_rank` + `fault.kill_round` | off | kill that rank at that collective round: the run fails over to the survivors and keeps training |
//! | `telemetry.trace_path` | off | write the phase-span journal as a Chrome `chrome://tracing` trace when the run finishes |
//! | `telemetry.listen` | off | serve the Prometheus text endpoint on this address (e.g. `127.0.0.1:0`) for the life of the run |

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::api::{
    Backend, CompressorSpec, FaultSpec, ModelSpec, Pipeline, Session, SourceFactory,
    StagedAlgo,
};
use crate::config::Config;
use crate::telemetry::TelemetrySink;
use crate::util::Rng;

use super::{GradientSource, WorkerPool};

/// Synthetic heterogeneous quadratic: f_i(x) = 0.5 ||x - c_i||^2 with
/// optional gradient noise. Cheap enough that the round cost is
/// dominated by what this driver exists to measure — the wire. Shared by
/// the coordinator tests and the net parity/loopback/chaos suites (one
/// oracle, not five copies).
pub struct Quad {
    center: Vec<f32>,
    noise: f32,
    rng: Rng,
}

impl GradientSource for Quad {
    fn dim(&self) -> usize {
        self.center.len()
    }

    fn grad(&mut self, params: &[f32], _round: usize) -> (f32, Vec<f32>) {
        let g: Vec<f32> = params
            .iter()
            .zip(&self.center)
            .map(|(&x, &c)| x - c + self.noise * self.rng.normal_f32())
            .collect();
        let loss = 0.5
            * params
                .iter()
                .zip(&self.center)
                .map(|(&x, &c)| (x - c) * (x - c))
                .sum::<f32>();
        (loss, g)
    }
}

/// One [`Quad`] factory per rank: rank i draws its center from
/// `Rng::new(seed + i)` (so callers can recompute the optimum), then
/// keeps the stream for its gradient noise.
pub fn quad_factories(n: usize, d: usize, seed: u64, noise: f32) -> Vec<SourceFactory> {
    (0..n)
        .map(|i| {
            let f: SourceFactory = Box::new(move || {
                let mut rng = Rng::new(seed + i as u64);
                Box::new(Quad { center: rng.normal_vec(d, 1.0), noise, rng })
                    as Box<dyn GradientSource>
            });
            f
        })
        .collect()
}

/// A spawned pool of [`Quad`] oracles (the tests' shared fixture).
pub fn quad_pool(n: usize, d: usize, seed: u64, noise: f32) -> WorkerPool {
    WorkerPool::spawn(quad_factories(n, d, seed, noise))
}

/// Fault spec from the `fault.*` knobs; None when no chaos is requested.
/// A malformed `fault.kill_rank` is a typed error, not a silently
/// different experiment; range/world checks happen at `build()`.
/// `job_seed` is the default fault-stream seed (the legacy contract).
pub(crate) fn fault_spec(cfg: &Config, job_seed: u64) -> Result<Option<FaultSpec>> {
    let spec = FaultSpec {
        seed: Some(cfg.parsed_or("fault.seed", job_seed)?),
        drop: cfg.parsed_or("fault.drop", 0.0)?,
        dup: cfg.parsed_or("fault.dup", 0.0)?,
        corrupt: cfg.parsed_or("fault.corrupt", 0.0)?,
        truncate: cfg.parsed_or("fault.truncate", 0.0)?,
        delay: cfg.parsed_or("fault.delay", 0.0)?,
        kill: match cfg.get("fault.kill_rank") {
            None => None,
            Some(r) => {
                let rank: usize = r
                    .parse()
                    .map_err(|_| anyhow!("fault.kill_rank {r:?} is not a rank"))?;
                Some((rank, cfg.parsed_or("fault.kill_round", 0u32)?))
            }
        },
    };
    Ok(spec.is_chaotic().then_some(spec))
}

/// `algo=` knob (shared with `repro trace`).
pub(crate) fn staged_algo(cfg: &Config) -> Result<StagedAlgo> {
    Ok(match cfg.str_or("algo", "ring") {
        "ring" => StagedAlgo::Ring,
        "halving" => StagedAlgo::Halving,
        "two-level" => StagedAlgo::TwoLevel {
            group: cfg.parsed_or("hierarchy.group_size", 4usize)?,
        },
        other => {
            return Err(anyhow!(
                "unknown staged algo {other:?} (ring|halving|two-level)"
            ))
        }
    })
}

/// `pipeline=` knob (shared with `repro trace`, which defaults streamed).
pub(crate) fn pipeline_knob(cfg: &Config, default: &str) -> Result<Pipeline> {
    match cfg.str_or("pipeline", default) {
        "barrier" => Ok(Pipeline::Barrier),
        "streamed" => Ok(Pipeline::Streamed),
        other => Err(anyhow!("unknown pipeline {other:?} (barrier|streamed)")),
    }
}

/// `transport=` knob (shared with `repro trace`, which defaults channel).
pub(crate) fn transport_knob(
    cfg: &Config,
    default: &str,
    algo: StagedAlgo,
) -> Result<(Backend, &'static str)> {
    match cfg.str_or("transport", default) {
        "tcp" => Ok((Backend::Tcp { algo }, "tcp-loopback")),
        "channel" => Ok((Backend::Channel { algo }, "in-proc channels")),
        other => Err(anyhow!("unknown transport {other:?} (tcp|channel)")),
    }
}

pub fn run(cfg: &Config) -> Result<()> {
    let n = cfg.parsed_or("workers", 4usize)?;
    let d = cfg.parsed_or("d", 1usize << 16)?;
    let rounds = cfg.parsed_or("rounds", 20usize)?;
    let lr = cfg.parsed_or("lr", 0.2f32)?;
    let seed = cfg.parsed_or("seed", 100u64)?;
    let algo = staged_algo(cfg)?;
    let pipeline = pipeline_knob(cfg, "barrier")?;
    let (backend, label) = transport_knob(cfg, "tcp", algo)?;
    let faults = fault_spec(cfg, seed)?;
    let chaos = faults.is_some();

    let mut builder = Session::builder()
        .world(n)
        .model(ModelSpec::flat(d))
        .sources(quad_factories(n, d, seed, 0.01))
        .compressor(CompressorSpec::parse("intsgd_random8")?)
        .seed(seed ^ 0x5EED)
        .lr(lr)
        .backend(backend)
        .pipeline(pipeline)
        .net_timeout(Duration::from_millis(cfg.parsed_or(
            "net.timeout_ms",
            crate::net::default_io_timeout().as_millis() as u64,
        )?))
        .net_retries(cfg.parsed_or("net.retries", 8usize)?);
    if let Some(f) = faults {
        builder = builder.faults(f);
    }
    if let Some(path) = cfg.get("telemetry.trace_path") {
        builder = builder.trace_path(path);
    }
    if let Some(addr) = cfg.get("telemetry.listen") {
        builder = builder.metrics_listen(addr);
    }
    let mut session = builder.build()?;

    println!(
        "net-bench: {} over {label}{} ({algo:?}), n = {n}, d = {d}, {rounds} rounds",
        session.algorithm(),
        if chaos { "+faults" } else { "" },
    );
    if let Some(addr) = session.metrics_addr() {
        println!("  metrics: http://{addr}/metrics");
    }
    let mut sink = TelemetrySink::new();
    session.run_observed(rounds, &mut sink)?;

    let records = session.records();
    let first = records.first().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let last = records.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let stats = session.wire_stats().expect("transport backend has wire stats");
    println!(
        "  train loss {first:.4} -> {last:.4}; {} staged collectives \
         (last wire {:?}, {} retried attempts, {} stale frames skipped)",
        stats.collectives, stats.last_wire, sink.retries(), stats.stale_skipped,
    );
    println!(
        "  integer-round wire time: measured {:.3} ms, modeled {:.3} ms \
         (ratio {:.2})",
        sink.measured() * 1e3,
        sink.modeled_int() * 1e3,
        sink.measured() / sink.modeled_int().max(1e-12)
    );
    if last.is_nan() || last >= first {
        return Err(anyhow!(
            "training over {label} made no progress: {first} -> {last}"
        ));
    }

    // a few more observed rounds: the per-round measured-vs-modeled
    // breakdown table (at the post-failover world size, if a rank died)
    println!("\n  round breakdown (seconds measured on this machine):");
    sink.begin_table();
    session.run_observed(3, &mut sink)?;
    session.finish();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn net_bench_runs_end_to_end_over_channels() {
        // the in-proc transport keeps this tier-1 fast & deterministic;
        // the TCP path is covered by tests/net_loopback.rs
        let mut cfg = Config::new();
        for kv in ["transport=channel", "workers=3", "d=512", "rounds=8"] {
            cfg.set_kv(kv).unwrap();
        }
        run(&cfg).expect("channel net-bench");
    }

    #[test]
    fn net_bench_streamed_two_level_runs_end_to_end() {
        // the streamed pipeline + hierarchical collective, over in-proc
        // channels, with the telemetry knobs on: the full knob path of
        // the overlap benchmarks, ending in a parseable Chrome trace
        let trace = std::env::temp_dir()
            .join(format!("intsgd_netbench_trace_{}.json", std::process::id()));
        let mut cfg = Config::new();
        for kv in [
            "transport=channel",
            "workers=4",
            "d=512",
            "rounds=8",
            "algo=two-level",
            "hierarchy.group_size=2",
            "pipeline=streamed",
            "telemetry.listen=127.0.0.1:0",
        ] {
            cfg.set_kv(kv).unwrap();
        }
        cfg.set_kv(&format!("telemetry.trace_path={}", trace.display())).unwrap();
        run(&cfg).expect("streamed two-level net-bench");
        let text = std::fs::read_to_string(&trace).expect("trace written");
        crate::util::json::Json::parse(&text).expect("trace is valid JSON");
        let _ = std::fs::remove_file(&trace);
    }

    #[test]
    fn net_bench_survives_injected_chaos() {
        // seeded recoverable faults over the channel transport: the run
        // must converge exactly as if the fabric were clean (bit-parity
        // is pinned in tests/chaos.rs; here: end-to-end knob plumbing)
        let mut cfg = Config::new();
        for kv in [
            "transport=channel",
            "workers=3",
            "d=256",
            "rounds=6",
            "fault.corrupt=0.02",
            "fault.dup=0.02",
            "net.timeout_ms=300",
            "net.retries=64",
        ] {
            cfg.set_kv(kv).unwrap();
        }
        run(&cfg).expect("chaotic channel net-bench");
    }

    #[test]
    fn rejects_unknown_knobs() {
        let mut cfg = Config::new();
        cfg.set_kv("transport=carrier-pigeon").unwrap();
        assert!(run(&cfg).is_err());
        let mut cfg = Config::new();
        cfg.set_kv("algo=butterfly").unwrap();
        assert!(run(&cfg).is_err());
        // malformed / out-of-world kill targets are typed errors, not a
        // silently different chaos experiment
        let mut cfg = Config::new();
        cfg.set_kv("fault.kill_rank=rank2").unwrap();
        assert!(run(&cfg).unwrap_err().to_string().contains("not a rank"));
        let mut cfg = Config::new();
        for kv in ["workers=4", "fault.kill_rank=9"] {
            cfg.set_kv(kv).unwrap();
        }
        assert!(run(&cfg).unwrap_err().to_string().contains("outside the world"));
        // a malformed numeric knob is a parse error, not a silent default
        let mut cfg = Config::new();
        cfg.set_kv("net.timeout_ms=soon").unwrap();
        assert!(run(&cfg).unwrap_err().to_string().contains("net.timeout_ms"));
        // a negative fault probability is an error, not silently "no chaos"
        // (even when the knobs sum to zero)
        let mut cfg = Config::new();
        for kv in ["transport=channel", "fault.drop=-0.3", "fault.dup=0.3"] {
            cfg.set_kv(kv).unwrap();
        }
        assert!(run(&cfg).unwrap_err().to_string().contains("[0, 1]"));
        // halving-doubling needs a power-of-two world — at build(), before
        // any socket exists
        let mut cfg = Config::new();
        for kv in ["transport=channel", "workers=3", "algo=halving"] {
            cfg.set_kv(kv).unwrap();
        }
        assert!(run(&cfg).unwrap_err().to_string().contains("power-of-two"));
        // a two-level group must divide the world — at build()
        let mut cfg = Config::new();
        for kv in [
            "transport=channel",
            "workers=4",
            "algo=two-level",
            "hierarchy.group_size=3",
        ] {
            cfg.set_kv(kv).unwrap();
        }
        assert!(run(&cfg).unwrap_err().to_string().contains("divides the world"));
        // unknown pipeline names are rejected before anything spawns
        let mut cfg = Config::new();
        cfg.set_kv("pipeline=warp").unwrap();
        assert!(run(&cfg).unwrap_err().to_string().contains("barrier|streamed"));
    }
}
