//! `repro net-bench` — full IntSGD training rounds over a real transport.
//!
//! The multi-thread-loopback driver: n worker threads compute gradients
//! and encode (as in every other driver), but the integer aggregation
//! leaves the leader's address space — a `net::TransportReducer` runs the
//! staged ring (or halving) all-reduce over loopback TCP sockets (or
//! in-process channels), moving the same framed bytes a multi-node
//! deployment would. Afterwards the driver replays a few standalone
//! rounds to print `netsim`'s **measured-vs-modeled** breakdown: real
//! socket wall-clock next to the alpha-beta cost of the identical wire
//! schedule ([`Network::round_breakdown_net`]), plus the fault/retry
//! account when chaos is injected.
//!
//!   repro net-bench workers=4 d=65536 rounds=20 transport=tcp algo=ring
//!
//! Knobs (`key=value`):
//!
//! | key | default | meaning |
//! |-----|---------|---------|
//! | `workers`, `d`, `rounds`, `lr`, `seed` | 4, 2^16, 20, 0.2, 100 | job shape |
//! | `transport` | `tcp` | `tcp` or `channel` |
//! | `algo` | `ring` | `ring` or `halving` |
//! | `net.timeout_ms` | 30000 (env `INTSGD_NET_TIMEOUT_MS`) | blocking-IO deadline; expiry is a typed `NetError::Timeout`, not a generic error |
//! | `net.retries` | 8 | retried attempts per collective before giving up |
//! | `fault.drop` / `fault.dup` / `fault.corrupt` / `fault.truncate` / `fault.delay` | 0 | per-frame fault probabilities (seeded, deterministic) |
//! | `fault.seed` | `seed` | fault-stream seed |
//! | `fault.kill_rank` + `fault.kill_round` | off | kill that rank at that collective round: the run fails over to the survivors and keeps training |

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::compress::intsgd::{IntSgd, Rounding, WireInt};
use crate::compress::RoundEngine;
use crate::config::Config;
use crate::net::{
    FaultPlan, KillAt, StagedAlgo, Transport, TransportReducer,
};
use crate::netsim::Network;
use crate::scaling::MovingAverageRule;
use crate::util::Rng;

use super::{
    BlockInfo, Coordinator, GradientSource, LrSchedule, RoundCtx, TrainConfig, WorkerPool,
};

/// Synthetic heterogeneous quadratic: f_i(x) = 0.5 ||x - c_i||^2 with
/// optional gradient noise. Cheap enough that the round cost is
/// dominated by what this driver exists to measure — the wire. Shared by
/// the coordinator tests and the net parity/loopback/chaos suites (one
/// oracle, not five copies).
pub struct Quad {
    center: Vec<f32>,
    noise: f32,
    rng: Rng,
}

impl GradientSource for Quad {
    fn dim(&self) -> usize {
        self.center.len()
    }

    fn grad(&mut self, params: &[f32], _round: usize) -> (f32, Vec<f32>) {
        let g: Vec<f32> = params
            .iter()
            .zip(&self.center)
            .map(|(&x, &c)| x - c + self.noise * self.rng.normal_f32())
            .collect();
        let loss = 0.5
            * params
                .iter()
                .zip(&self.center)
                .map(|(&x, &c)| (x - c) * (x - c))
                .sum::<f32>();
        (loss, g)
    }
}

/// A worker pool of [`Quad`] oracles: rank i draws its center from
/// `Rng::new(seed + i)` (so callers can recompute the optimum), then
/// keeps the stream for its gradient noise.
pub fn quad_pool(n: usize, d: usize, seed: u64, noise: f32) -> WorkerPool {
    let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> = (0..n)
        .map(|i| {
            let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                Box::new(move || {
                    let mut rng = Rng::new(seed + i as u64);
                    Box::new(Quad {
                        center: rng.normal_vec(d, 1.0),
                        noise,
                        rng,
                    }) as Box<dyn GradientSource>
                });
            f
        })
        .collect();
    WorkerPool::spawn(factories)
}

fn intsgd_engine(n: usize, seed: u64) -> RoundEngine {
    RoundEngine::new(Box::new(IntSgd::new(
        Rounding::Stochastic,
        WireInt::Int8,
        Box::new(MovingAverageRule::default_paper()),
        n,
        seed,
    )))
}

/// Fault plan from the `fault.*` knobs; None when no chaos is requested.
/// A malformed or out-of-world `fault.kill_rank` is a typed error, not a
/// silently different experiment (the driver's contract, like
/// transport/algo).
fn fault_plan(
    cfg: &Config,
    seed: u64,
    workers: usize,
) -> Result<(Option<FaultPlan>, Option<(usize, KillAt)>)> {
    let plan = FaultPlan {
        seed: cfg.u64_or("fault.seed", seed),
        drop_p: cfg.f64_or("fault.drop", 0.0),
        dup_p: cfg.f64_or("fault.dup", 0.0),
        corrupt_p: cfg.f64_or("fault.corrupt", 0.0),
        truncate_p: cfg.f64_or("fault.truncate", 0.0),
        delay_p: cfg.f64_or("fault.delay", 0.0),
    };
    let ps = [plan.drop_p, plan.dup_p, plan.corrupt_p, plan.truncate_p, plan.delay_p];
    if ps.iter().any(|p| !(0.0..=1.0).contains(p)) || ps.iter().sum::<f64>() > 1.0 {
        return Err(anyhow!(
            "fault.* probabilities must each lie in [0, 1] and sum to at most 1 \
             (got drop={} dup={} corrupt={} truncate={} delay={})",
            ps[0], ps[1], ps[2], ps[3], ps[4]
        ));
    }
    let kill = match cfg.get("fault.kill_rank") {
        None => None,
        Some(r) => {
            let rank: usize = r
                .parse()
                .map_err(|_| anyhow!("fault.kill_rank {r:?} is not a rank"))?;
            if rank >= workers {
                return Err(anyhow!(
                    "fault.kill_rank {rank} outside the world of {workers} workers"
                ));
            }
            let round = cfg.u64_or("fault.kill_round", 0) as u32;
            Some((rank, KillAt::Round(round)))
        }
    };
    let any = plan.drop_p + plan.dup_p + plan.corrupt_p + plan.truncate_p + plan.delay_p
        > 0.0;
    Ok((any.then_some(plan), kill))
}

/// One net-bench job's shape + failure-model knobs.
#[derive(Clone, Copy)]
struct Job {
    n: usize,
    d: usize,
    rounds: usize,
    lr: f32,
    seed: u64,
    timeout: Duration,
    max_retries: usize,
}

/// Train + measure over a concrete transport (monomorphized per mesh).
fn drive<T: Transport>(
    mut red: TransportReducer<T>,
    label: &str,
    job: &Job,
) -> Result<()> {
    let Job { n, d, rounds, lr, seed, timeout, max_retries } = *job;
    let red = &mut red;
    red.set_timeout(timeout);
    red.set_max_retries(max_retries);
    let net = Network::tcp_loopback();
    let mut pool = quad_pool(n, d, seed, 0.01);
    let mut coord = Coordinator::new(vec![0.0; d], vec![d], net.clone());
    let mut engine = intsgd_engine(n, seed ^ 0x5EED);
    let cfg = TrainConfig {
        rounds,
        schedule: LrSchedule::constant(lr),
        ..Default::default()
    };

    println!(
        "net-bench: intsgd_random_int8 over {label} ({:?}), n = {n}, d = {d}, {rounds} rounds",
        red.algo()
    );
    let res = coord.train_over(&mut pool, &mut engine, &mut *red, &cfg, None);
    let first = res.records.first().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let last = res.records.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
    let modeled_int: f64 =
        res.records.iter().skip(1).map(|r| r.comm_seconds).sum();
    let measured = red.take_wire_seconds();
    let retries = red.take_retries();
    println!(
        "  train loss {first:.4} -> {last:.4}; {} staged collectives \
         (last wire {:?}, {retries} retried attempts, {} stale frames skipped)",
        red.calls(),
        red.last_wire(),
        red.stale_skipped(),
    );
    for (round, rank) in &res.failovers {
        println!("  FAILOVER: rank {rank} died in round {round}; world shrank and trained on");
    }
    println!(
        "  integer-round wire time: measured {:.3} ms, modeled {:.3} ms \
         (ratio {:.2})",
        measured * 1e3,
        modeled_int * 1e3,
        measured / modeled_int.max(1e-12)
    );
    if last.is_nan() || last >= first {
        return Err(anyhow!(
            "training over {label} made no progress: {first} -> {last}"
        ));
    }

    // standalone rounds: the per-round measured-vs-modeled breakdown
    // (run at the post-failover world size, if any rank died)
    let n = pool.workers();
    println!("\n  round breakdown (seconds measured on this machine):");
    println!(
        "  {:<8} {:>12} {:>12} {:>12} {:>14} {:>14} {:>8}",
        "round", "encode", "reduce", "decode", "comm_model", "comm_measured", "retries"
    );
    let ctx = RoundCtx {
        round: rounds.max(1),
        n,
        d,
        lr,
        step_norm_sq: 1e-4,
        blocks: vec![BlockInfo { dim: d, step_norm_sq: 1e-4 }],
    };
    for k in 0..3 {
        let (grads, _, _) = pool.compute_round(&coord.params, rounds + k);
        let result = engine
            .round_parallel_over(&mut pool, &mut *red, &grads, &ctx)
            .map_err(|e| anyhow!("standalone breakdown round failed: {e}"))?;
        let b = net.round_breakdown_net(
            &result,
            n,
            red.take_wire_seconds(),
            red.take_retries(),
        );
        println!(
            "  {:<8} {:>12.6} {:>12.6} {:>12.6} {:>14.6} {:>14.6} {:>8}",
            k, b.encode, b.reduce, b.decode, b.comm_model, b.comm_measured, b.comm_retries
        );
        engine.reclaim(result);
    }
    pool.shutdown();
    Ok(())
}

pub fn run(cfg: &Config) -> Result<()> {
    let n = cfg.usize_or("workers", 4);
    let d = cfg.usize_or("d", 1 << 16);
    let rounds = cfg.usize_or("rounds", 20);
    let lr = cfg.f32_or("lr", 0.2);
    let seed = cfg.u64_or("seed", 100);
    let algo = match cfg.str_or("algo", "ring") {
        "ring" => StagedAlgo::Ring,
        "halving" => StagedAlgo::Halving,
        other => return Err(anyhow!("unknown staged algo {other:?} (ring|halving)")),
    };
    let (plan, kill) = fault_plan(cfg, seed, n)?;
    let chaos = plan.is_some() || kill.is_some();
    let job = Job {
        n,
        d,
        rounds,
        lr,
        seed,
        timeout: Duration::from_millis(cfg.u64_or(
            "net.timeout_ms",
            crate::net::default_io_timeout().as_millis() as u64,
        )),
        max_retries: cfg.usize_or("net.retries", 8),
    };
    let plan = plan.unwrap_or_else(|| FaultPlan::clean(seed));
    match cfg.str_or("transport", "tcp") {
        "tcp" => {
            let mesh = crate::net::TcpTransport::loopback_mesh(n)?;
            if chaos {
                let wrapped = crate::net::FaultTransport::wrap_mesh(mesh, &plan, kill);
                drive(TransportReducer::new(wrapped, algo), "tcp-loopback+faults", &job)
            } else {
                drive(TransportReducer::new(mesh, algo), "tcp-loopback", &job)
            }
        }
        "channel" => {
            let mesh = crate::net::ChannelTransport::mesh(n);
            if chaos {
                let wrapped = crate::net::FaultTransport::wrap_mesh(mesh, &plan, kill);
                drive(
                    TransportReducer::new(wrapped, algo),
                    "in-proc channels+faults",
                    &job,
                )
            } else {
                drive(TransportReducer::new(mesh, algo), "in-proc channels", &job)
            }
        }
        other => Err(anyhow!("unknown transport {other:?} (tcp|channel)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn net_bench_runs_end_to_end_over_channels() {
        // the in-proc transport keeps this tier-1 fast & deterministic;
        // the TCP path is covered by tests/net_loopback.rs
        let mut cfg = Config::new();
        for kv in ["transport=channel", "workers=3", "d=512", "rounds=8"] {
            cfg.set_kv(kv).unwrap();
        }
        run(&cfg).expect("channel net-bench");
    }

    #[test]
    fn net_bench_survives_injected_chaos() {
        // seeded recoverable faults over the channel transport: the run
        // must converge exactly as if the fabric were clean (bit-parity
        // is pinned in tests/chaos.rs; here: end-to-end knob plumbing)
        let mut cfg = Config::new();
        for kv in [
            "transport=channel",
            "workers=3",
            "d=256",
            "rounds=6",
            "fault.corrupt=0.02",
            "fault.dup=0.02",
            "net.timeout_ms=300",
            "net.retries=64",
        ] {
            cfg.set_kv(kv).unwrap();
        }
        run(&cfg).expect("chaotic channel net-bench");
    }

    #[test]
    fn rejects_unknown_knobs() {
        let mut cfg = Config::new();
        cfg.set_kv("transport=carrier-pigeon").unwrap();
        assert!(run(&cfg).is_err());
        let mut cfg = Config::new();
        cfg.set_kv("algo=butterfly").unwrap();
        assert!(run(&cfg).is_err());
        // malformed / out-of-world kill targets are typed errors, not a
        // silently different chaos experiment
        let mut cfg = Config::new();
        cfg.set_kv("fault.kill_rank=rank2").unwrap();
        assert!(run(&cfg).unwrap_err().to_string().contains("not a rank"));
        let mut cfg = Config::new();
        for kv in ["workers=4", "fault.kill_rank=9"] {
            cfg.set_kv(kv).unwrap();
        }
        assert!(run(&cfg).unwrap_err().to_string().contains("outside the world"));
    }
}
