//! Layer-3 distributed-training coordinator.
//!
//! A leader thread owns the model parameters and the optimization loop; n
//! worker threads own their data shards and compute engines (PJRT
//! executables or native gradient oracles) and exchange messages with the
//! leader over channels — the same synchronous data-parallel round
//! structure as the paper's 16-GPU PyTorch/NCCL setup:
//!
//!   leader                         workers (n threads)
//!   ------                         -------------------
//!   broadcast x^k     ──────────▶  compute g_i^k on local shard
//!   collect g_i^k     ◀──────────  send gradient
//!   share grad views  ──────────▶  encode in place (rank-local state)
//!   collect acks      ◀──────────  typed wire message ready
//!   reduce (integer sums chunked back across the pool) + decode
//!   optimizer step -> x^{k+1}; account comm time via netsim;
//!   hand round buffers back (RoundEngine::reclaim)
//!
//! The encode phase of each compression round runs *inside the worker
//! threads* (`RoundEngine::round_parallel`), so the recorded overhead is
//! the straggler max a real synchronous round pays — not an n-fold
//! serialization on the leader divided by n after the fact. Steady-state
//! compression rounds are allocation-free (see `compress::engine`).
//!
//! Workers that need non-Send resources (PJRT clients are Rc-backed)
//! construct them inside their own thread from a `Send` factory.

pub mod net_driver;
pub mod pjrt_worker;
pub mod serve_cmd;
pub mod trace_cmd;
pub mod worker;

pub use pjrt_worker::{BatchSpec, PjrtEvaluator, PjrtWorker};
pub use worker::{GradientSource, WorkerPool};

use crate::compress::engine::{Pipeline, Reducer, RoundEngine};
use crate::net::NetError;
use crate::netsim::{Network, RoundBreakdown};
use crate::optim::Sgd;
use crate::runtime::Checkpoint;
use crate::telemetry;
use crate::util::stats::l2_diff_norm_sq;

/// Per-parameter-block geometry handed to scaling rules (Alg. 2).
#[derive(Clone, Debug)]
pub struct BlockInfo {
    pub dim: usize,
    /// ||(x^k)_l - (x^{k-1})_l||^2 for this block.
    pub step_norm_sq: f64,
}

/// Everything a compressor / scaling rule may consult in one round.
#[derive(Clone, Debug)]
pub struct RoundCtx {
    pub round: usize,
    /// Worker count.
    pub n: usize,
    /// Flattened gradient dimension.
    pub d: usize,
    /// Step size eta_k in effect this round.
    pub lr: f32,
    /// ||x^k - x^{k-1}||^2.
    pub step_norm_sq: f64,
    /// Per-block dims and step norms (empty when the layout is unknown).
    pub blocks: Vec<BlockInfo>,
}

/// Learning-rate schedule: linear warmup then stepwise decay, the recipe
/// of the paper's §C.1 (5 warmup epochs; /10 at given milestones).
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub base: f32,
    pub warmup_rounds: usize,
    /// (round, factor) pairs; factor applies from that round on.
    pub milestones: Vec<(usize, f32)>,
}

impl LrSchedule {
    pub fn constant(base: f32) -> Self {
        LrSchedule { base, warmup_rounds: 0, milestones: vec![] }
    }

    pub fn lr_at(&self, round: usize) -> f32 {
        let mut lr = self.base;
        if self.warmup_rounds > 0 && round < self.warmup_rounds {
            lr *= (round + 1) as f32 / self.warmup_rounds as f32;
        }
        for &(at, factor) in &self.milestones {
            if round >= at {
                lr *= factor;
            }
        }
        lr
    }
}

/// One row of the training log.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub train_loss: f64,
    pub lr: f32,
    pub alpha: f64,
    pub max_abs_int: i64,
    pub wire_bytes_per_worker: usize,
    /// Measured seconds: worker compute (max across workers), compression
    /// encode (straggler max across workers) + decode (edge folds and the
    /// final decode; in-flight reductions are charged to `comm_seconds`).
    pub compute_seconds: f64,
    pub overhead_seconds: f64,
    /// Modeled seconds from the network cost model.
    pub comm_seconds: f64,
}

/// Training driver configuration.
pub struct TrainConfig {
    pub rounds: usize,
    /// First round to run (nonzero when resuming from a checkpoint: the
    /// loop covers `start_round..rounds` and the schedule stays aligned).
    pub start_round: usize,
    pub schedule: LrSchedule,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Evaluate every `eval_every` rounds (0 = never).
    pub eval_every: usize,
    /// Round driver: classic barrier phases, or the double-buffered block
    /// pipeline overlapping encode/reduce/decode. Streamed requires an
    /// external reducer (`train_over`); rounds a compressor cannot stream
    /// (round 0, multi-pass, all-gather, switch) fall back to barrier
    /// per-round, bit-identically.
    pub pipeline: Pipeline,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            rounds: 100,
            start_round: 0,
            schedule: LrSchedule::constant(0.1),
            momentum: 0.0,
            weight_decay: 0.0,
            eval_every: 0,
            pipeline: Pipeline::Barrier,
        }
    }
}

/// Streaming per-round callbacks — the `api::Session` hook that replaces
/// ad-hoc "collect vecs, post-process later" plumbing. Every method has a
/// no-op default, so observers implement only what they watch.
pub trait RoundObserver {
    /// After every completed round: the record just logged, plus the
    /// netsim breakdown for the round (carrying measured wire time and
    /// the retry count when the reduce ran over a real transport, the
    /// modeled comm cost otherwise).
    fn on_round(&mut self, _record: &RoundRecord, _breakdown: &RoundBreakdown) {}

    /// After each eval-hook invocation (`TrainConfig::eval_every`).
    fn on_eval(&mut self, _round: usize, _loss: f64, _accuracy: f64) {}

    /// A rank died mid-round; the world shrank to the survivors and the
    /// round is being re-run at the smaller n.
    fn on_failover(&mut self, _round: usize, _dead_rank: usize) {}
}

/// Per-run mutable state the round loop threads through: the optimizer
/// (momentum), the accumulating log, and the reused block-norm buffer.
/// Extracted from the monolithic training loop so single rounds can be
/// driven externally ([`Coordinator::run_round`] — what `api::Session`'s
/// `step()` is built on) without losing momentum state between calls.
pub struct TrainState {
    opt: Sgd,
    records: Vec<RoundRecord>,
    evals: Vec<(usize, f64, f64)>,
    failovers: Vec<(usize, usize)>,
    blocks: Vec<BlockInfo>,
    next_round: usize,
}

impl TrainState {
    /// The next round this run will execute.
    pub fn round(&self) -> usize {
        self.next_round
    }

    pub fn records(&self) -> &[RoundRecord] {
        &self.records
    }

    pub fn evals(&self) -> &[(usize, f64, f64)] {
        &self.evals
    }

    pub fn failovers(&self) -> &[(usize, usize)] {
        &self.failovers
    }
}

/// Result of a full training run.
pub struct TrainResult {
    pub records: Vec<RoundRecord>,
    /// (round, eval metric(s)) — model-specific: (loss, accuracy?) pairs.
    pub evals: Vec<(usize, f64, f64)>,
    pub final_params: Vec<f32>,
    /// World shrinks that happened mid-run: (round, dead rank at the time
    /// of death). Empty on a healthy fabric.
    pub failovers: Vec<(usize, usize)>,
}

/// The leader: drives `rounds` synchronous rounds over the worker pool.
pub struct Coordinator {
    pub params: Vec<f32>,
    prev_params: Vec<f32>,
    /// Parameter-block dims, in flattening order (for Alg. 2 & PowerSGD).
    pub block_dims: Vec<usize>,
    pub network: Network,
}

impl Coordinator {
    pub fn new(init_params: Vec<f32>, block_dims: Vec<usize>, network: Network) -> Self {
        let prev = init_params.clone();
        Coordinator { params: init_params, prev_params: prev, block_dims, network }
    }

    /// Per-block step norms, fused over the param/prev pair — no
    /// temporary diff vectors (this runs every round).
    fn block_infos(&self, out: &mut Vec<BlockInfo>) {
        out.clear();
        if self.block_dims.is_empty() {
            out.push(BlockInfo {
                dim: self.params.len(),
                step_norm_sq: l2_diff_norm_sq(&self.params, &self.prev_params),
            });
            return;
        }
        let mut off = 0;
        for &dim in &self.block_dims {
            let sq = l2_diff_norm_sq(
                &self.params[off..off + dim],
                &self.prev_params[off..off + dim],
            );
            out.push(BlockInfo { dim, step_norm_sq: sq });
            off += dim;
        }
        debug_assert_eq!(off, self.params.len(), "block dims must tile the params");
    }

    /// Run the synchronous training loop (integer reductions on the
    /// pool's coordinate-chunked fold).
    pub fn train(
        &mut self,
        pool: &mut WorkerPool,
        engine: &mut RoundEngine,
        cfg: &TrainConfig,
        eval: Option<&mut dyn FnMut(&[f32]) -> (f64, f64)>,
    ) -> TrainResult {
        self.train_impl(pool, engine, None, cfg, eval)
    }

    /// [`Coordinator::train`] with the integer reduce phase handed to an
    /// external [`Reducer`] — how `repro net-bench` runs full IntSGD
    /// rounds over a real transport (`net::TransportReducer`): gradients
    /// and encodes stay on the worker threads, the aggregation leaves the
    /// process boundary behind and moves framed bytes between ranks.
    pub fn train_over(
        &mut self,
        pool: &mut WorkerPool,
        engine: &mut RoundEngine,
        red: &mut dyn Reducer,
        cfg: &TrainConfig,
        eval: Option<&mut dyn FnMut(&[f32]) -> (f64, f64)>,
    ) -> TrainResult {
        self.train_impl(pool, engine, Some(red), cfg, eval)
    }

    fn train_impl(
        &mut self,
        pool: &mut WorkerPool,
        engine: &mut RoundEngine,
        mut red: Option<&mut dyn Reducer>,
        cfg: &TrainConfig,
        mut eval: Option<&mut dyn FnMut(&[f32]) -> (f64, f64)>,
    ) -> TrainResult {
        let mut st = self.begin(cfg);
        while st.next_round < cfg.rounds {
            if let Err(e) = self.run_round(
                &mut st,
                pool,
                engine,
                red.as_deref_mut(),
                cfg,
                eval.as_deref_mut(),
                None,
            ) {
                panic!("unrecoverable collective failure: {e}");
            }
        }
        self.finish_run(st)
    }

    /// Start a run: fresh optimizer (momentum lives here) and an empty
    /// log, positioned at `cfg.start_round`. Pair with
    /// [`Coordinator::run_round`] and [`Coordinator::finish_run`] — the
    /// exact code path `train`/`train_over` loop over, exposed so
    /// `api::Session` can drive rounds one at a time.
    pub fn begin(&self, cfg: &TrainConfig) -> TrainState {
        TrainState {
            opt: Sgd::new(self.params.len(), cfg.momentum, cfg.weight_decay),
            records: Vec::with_capacity(cfg.rounds.saturating_sub(cfg.start_round)),
            evals: Vec::new(),
            failovers: Vec::new(),
            blocks: Vec::with_capacity(self.block_dims.len().max(1)),
            next_round: cfg.start_round,
        }
    }

    /// One synchronous round — the body of the training loop. On a
    /// permanent rank death the world shrinks to the survivors and the
    /// SAME round re-runs at the smaller n. The re-run is exactly a fresh
    /// round at n-1 (tests/chaos.rs): the alpha rules are
    /// round-idempotent, the stochastic-rounding base is round-keyed (a
    /// re-encode reuses it), and the dead rank's gradient simply leaves
    /// the average. Caveat: a *stateful noisy* GradientSource advances
    /// its noise stream on the recompute — survivor-parity is exact for
    /// the compression state, and for the data too whenever sources are
    /// deterministic functions of (params, round).
    ///
    /// An unrecoverable collective failure surfaces as the typed
    /// [`NetError`]; the state is left consistent (the failed round is
    /// simply not logged), so the caller may retry, resume elsewhere, or
    /// abort.
    #[allow(clippy::too_many_arguments)]
    pub fn run_round(
        &mut self,
        st: &mut TrainState,
        pool: &mut WorkerPool,
        engine: &mut RoundEngine,
        mut red: Option<&mut dyn Reducer>,
        cfg: &TrainConfig,
        mut eval: Option<&mut dyn FnMut(&[f32]) -> (f64, f64)>,
        mut obs: Option<&mut dyn RoundObserver>,
    ) -> Result<RoundRecord, NetError> {
        let d = self.params.len();
        let round = st.next_round;
        let lr = cfg.schedule.lr_at(round);
        let round_t0 = telemetry::journal::start();

        let (result, losses, compute_seconds, n) = loop {
            let n = pool.workers();

            // 1. broadcast params, collect worker gradients (threads)
            let compute_t0 = telemetry::journal::start();
            let (grads, losses, compute_seconds) =
                pool.compute_round(&self.params, round);
            telemetry::journal::record(
                telemetry::Phase::Compute,
                round as u32,
                telemetry::ALL,
                telemetry::ALL,
                compute_t0,
            );

            // 2. compress + aggregate: encode back on the worker
            //    threads, reduce + decode on the leader. The blocks
            //    tile the params, so the global step norm is their
            //    fused sum.
            self.block_infos(&mut st.blocks);
            let step_norm_sq = st.blocks.iter().map(|b| b.step_norm_sq).sum();
            let ctx = RoundCtx {
                round,
                n,
                d,
                lr,
                step_norm_sq,
                blocks: std::mem::take(&mut st.blocks),
            };
            let attempt = match (&mut red, cfg.pipeline) {
                (Some(r), Pipeline::Streamed) => {
                    engine.round_streamed_over(pool, &mut **r, &grads, &ctx)
                }
                (Some(r), Pipeline::Barrier) => {
                    engine.round_parallel_over(pool, &mut **r, &grads, &ctx)
                }
                (None, _) => Ok(engine.round_parallel(pool, &grads, &ctx)),
            };
            st.blocks = ctx.blocks; // reclaim the buffer for the next round
            match attempt {
                Ok(result) => break (result, losses, compute_seconds, n),
                Err(e) if e.is_peer_dead() && e.rank() < n && n > 1 => {
                    let dead = e.rank();
                    telemetry::m::FAILOVERS.inc();
                    st.failovers.push((round, dead));
                    if let Some(o) = obs.as_deref_mut() {
                        o.on_failover(round, dead);
                    }
                    pool.remove_worker(dead);
                    engine.remove_rank(dead);
                    if let Some(r) = &mut red {
                        r.remove_rank(dead);
                    }
                    // loop: recompute gradients and re-run at n - 1
                }
                Err(e) => {
                    // discard the failed round's wire measure so a later
                    // successful round's breakdown is not inflated by it
                    // (failover re-runs above keep theirs: the re-run IS
                    // the same logical round, and its retries are part of
                    // that round's cost)
                    if let Some(r) = &mut red {
                        let _ = r.take_wire_measure();
                    }
                    return Err(e);
                }
            }
        };

        // 3. optimizer step
        self.prev_params.copy_from_slice(&self.params);
        st.opt.step(&mut self.params, &result.gtilde, lr);

        // 4. account
        let comm_seconds = self.network.comm_seconds(&result.comm, n);
        let record = RoundRecord {
            round,
            train_loss: losses.iter().map(|&l| l as f64).sum::<f64>() / n as f64,
            lr,
            alpha: result.alpha,
            max_abs_int: result.max_abs_int,
            wire_bytes_per_worker: result.wire_bytes_per_worker(),
            compute_seconds,
            overhead_seconds: result.encode_seconds + result.decode_seconds,
            comm_seconds,
        };
        // feed the static registry — every driver, observer or not
        telemetry::observe_round(&telemetry::RoundStats {
            train_loss: record.train_loss,
            alpha: record.alpha,
            wire_bytes_per_worker: record.wire_bytes_per_worker,
            d,
            n,
            encode_seconds: result.encode_seconds,
            reduce_seconds: result.reduce_seconds,
            decode_seconds: result.decode_seconds,
        });
        telemetry::journal::record(
            telemetry::Phase::Round,
            round as u32,
            telemetry::ALL,
            telemetry::ALL,
            round_t0,
        );
        // drain the per-round wire measure unconditionally: an observer
        // attached mid-run must see THIS round's wire time, not the
        // accumulated backlog of every unobserved round before it
        let wire = red.as_mut().and_then(|r| r.take_wire_measure());
        if let Some((measured, _)) = wire {
            telemetry::m::COMM_SECONDS.record_secs(measured);
        }
        if let Some(o) = obs.as_deref_mut() {
            // measured wire time + retries when the reduce ran over a
            // real transport, the modeled comm cost otherwise
            let b = match wire {
                Some((wire, retries)) => {
                    self.network.round_breakdown_net(&result, n, wire, retries)
                }
                None => self.network.round_breakdown(&result, n),
            };
            o.on_round(&record, &b);
        }
        st.records.push(record.clone());
        // hand the round's buffers back so steady-state rounds stay
        // off the allocator
        engine.reclaim(result);
        st.next_round = round + 1;

        if cfg.eval_every > 0 && (round + 1) % cfg.eval_every == 0 {
            if let Some(f) = eval.as_deref_mut() {
                let (l, a) = f(&self.params);
                st.evals.push((round, l, a));
                if let Some(o) = obs.as_deref_mut() {
                    o.on_eval(round, l, a);
                }
            }
        }
        Ok(record)
    }

    /// Close a run started with [`Coordinator::begin`].
    pub fn finish_run(&self, st: TrainState) -> TrainResult {
        TrainResult {
            records: st.records,
            evals: st.evals,
            final_params: self.params.clone(),
            failovers: st.failovers,
        }
    }

    /// Layout synthesized from the block dims ("block{i}"), or one "flat"
    /// entry when the layout is unknown.
    fn checkpoint_layout(&self) -> Vec<(String, u64)> {
        if self.block_dims.is_empty() {
            return vec![("flat".to_string(), self.params.len() as u64)];
        }
        self.block_dims
            .iter()
            .enumerate()
            .map(|(i, &dim)| (format!("block{i}"), dim as u64))
            .collect()
    }

    /// Snapshot the full training state into a v2 [`Checkpoint`]: params,
    /// previous-round params (the scaling rules read `‖x^k − x^{k−1}‖²`),
    /// the rule's moving average, per-rank EF residuals, and per-rank
    /// encoder RNG streams — everything a bit-exact resume needs
    /// (`runtime::checkpoint` module docs; pinned by `tests/chaos.rs`).
    pub fn snapshot(&self, engine: &mut RoundEngine, round: u64) -> anyhow::Result<Checkpoint> {
        let mut ck = Checkpoint::new(round, self.checkpoint_layout(), self.params.clone())?;
        ck.prev_flat = Some(self.prev_params.clone());
        ck.rule_state = engine.export_rule_state();
        ck.ef_residuals = engine.export_ef();
        ck.rng_streams = engine.export_rng_streams();
        Ok(ck)
    }

    /// Restore a [`Checkpoint`] into this coordinator + a compatible
    /// engine for an `n`-rank world. Builds the engine's encoders first
    /// so per-rank state (EF residuals, RNG streams) has a home; resume
    /// training with `TrainConfig::start_round = ck.round`.
    pub fn restore(
        &mut self,
        engine: &mut RoundEngine,
        n: usize,
        ck: &Checkpoint,
    ) -> anyhow::Result<()> {
        ck.check_layout(&self.checkpoint_layout())?;
        self.params.clone_from(&ck.flat);
        match &ck.prev_flat {
            Some(prev) => self.prev_params.clone_from(prev),
            // v1 checkpoint: no previous params — start from a zero step
            None => self.prev_params.clone_from(&ck.flat),
        }
        engine.ensure_world(n);
        if let Some(rule) = &ck.rule_state {
            engine.import_rule_state(rule)?;
        }
        if !ck.ef_residuals.is_empty() {
            engine.import_ef(&ck.ef_residuals)?;
        }
        if !ck.rng_streams.is_empty() {
            engine.import_rng_streams(&ck.rng_streams)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::IdentitySgd;
    use crate::netsim::Network;
    use crate::util::Rng;

    /// The shared quadratic oracle (`net_driver::quad_pool`), centers
    /// drawn from `Rng::new(100 + i)` so tests can recompute the optimum.
    fn quad_pool(n: usize, d: usize, noise: f32) -> WorkerPool {
        net_driver::quad_pool(n, d, 100, noise)
    }

    fn identity_engine() -> RoundEngine {
        RoundEngine::new(Box::new(IdentitySgd::allreduce()))
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        // heterogeneous centers: the optimum is their mean, with a positive
        // loss floor f* = 0.5 mean_i ||x* - c_i||^2; SGD must reach it.
        let d = 64;
        let n = 4;
        let mut pool = quad_pool(n, d, 0.0);
        let mut coord =
            Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
        let mut engine = identity_engine();
        let cfg = TrainConfig {
            rounds: 200,
            schedule: LrSchedule::constant(0.5),
            ..Default::default()
        };
        let res = coord.train(&mut pool, &mut engine, &cfg, None);
        pool.shutdown();
        // recompute the centers the factories used
        let centers: Vec<Vec<f32>> = (0..n)
            .map(|i| Rng::new(100 + i as u64).normal_vec(d, 1.0))
            .collect();
        let xstar: Vec<f32> = (0..d)
            .map(|j| centers.iter().map(|c| c[j]).sum::<f32>() / n as f32)
            .collect();
        let fstar: f64 = centers
            .iter()
            .map(|c| {
                0.5 * c
                    .iter()
                    .zip(&xstar)
                    .map(|(&ci, &xi)| ((ci - xi) as f64).powi(2))
                    .sum::<f64>()
            })
            .sum::<f64>()
            / n as f64;
        let last = res.records.last().unwrap().train_loss;
        // params converge to x*: distance check + loss reaches the floor
        let dist: f64 = res
            .final_params
            .iter()
            .zip(&xstar)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(dist < 1e-9, "dist to optimum {dist}");
        assert!((last - fstar).abs() < 1e-3 * fstar.max(1.0), "{last} vs f* {fstar}");
    }

    #[test]
    fn lr_schedule_warmup_and_decay() {
        let s = LrSchedule {
            base: 1.0,
            warmup_rounds: 10,
            milestones: vec![(100, 0.1), (200, 0.1)],
        };
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(9) - 1.0).abs() < 1e-6);
        assert!((s.lr_at(50) - 1.0).abs() < 1e-6);
        assert!((s.lr_at(150) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(250) - 0.01).abs() < 1e-6);
    }

    #[test]
    fn records_account_every_round() {
        let d = 8;
        let mut pool = quad_pool(2, d, 0.1);
        let mut coord =
            Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
        let mut engine = identity_engine();
        let cfg = TrainConfig { rounds: 5, ..Default::default() };
        let res = coord.train(&mut pool, &mut engine, &cfg, None);
        pool.shutdown();
        assert_eq!(res.records.len(), 5);
        for (i, r) in res.records.iter().enumerate() {
            assert_eq!(r.round, i);
            assert_eq!(r.wire_bytes_per_worker, d * 4);
            assert!(r.comm_seconds > 0.0);
        }
    }

    #[test]
    fn eval_hook_invoked() {
        let d = 4;
        let mut pool = quad_pool(2, d, 0.0);
        let mut coord =
            Coordinator::new(vec![0.0; d], vec![d], Network::paper_cluster());
        let mut engine = identity_engine();
        let cfg = TrainConfig { rounds: 10, eval_every: 3, ..Default::default() };
        let mut calls = 0;
        let mut hook = |_p: &[f32]| {
            calls += 1;
            (0.0, 0.0)
        };
        let res = coord.train(&mut pool, &mut engine, &cfg, Some(&mut hook));
        pool.shutdown();
        assert_eq!(res.evals.len(), 3);
        assert_eq!(calls, 3);
    }

    #[test]
    fn intsgd_trains_with_per_block_alphas_through_the_pool() {
        // Multi-block layout + IntSGD through the parallel engine: the
        // end-to-end Alg. 2 path the refactor exists for.
        use crate::compress::intsgd::{IntSgd, Rounding, WireInt};
        use crate::scaling::BlockRule;
        let d = 48;
        let n = 3;
        let mut pool = quad_pool(n, d, 0.0);
        let mut coord = Coordinator::new(
            vec![0.0; d],
            vec![16, 24, 8],
            Network::paper_cluster(),
        );
        let mut engine = RoundEngine::new(Box::new(IntSgd::new(
            Rounding::Stochastic,
            WireInt::Int8,
            Box::new(BlockRule::new(0.9, 1e-8)),
            n,
            13,
        )));
        let cfg = TrainConfig {
            rounds: 150,
            schedule: LrSchedule::constant(0.4),
            ..Default::default()
        };
        let res = coord.train(&mut pool, &mut engine, &cfg, None);
        pool.shutdown();
        let first = res.records[0].train_loss;
        let last = res.records.last().unwrap().train_loss;
        assert!(last < first, "no progress: {first} -> {last}");
        // int8 aggregate budget respected every round
        assert!(res.records.iter().all(|r| r.max_abs_int <= 127));
        // after round 0 the wire is one byte per coordinate
        assert_eq!(res.records[1].wire_bytes_per_worker, d);
    }
}
