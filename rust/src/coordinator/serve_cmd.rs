//! `repro serve` — N concurrent training jobs over ONE shared socket
//! mesh, the [`crate::api::SessionServer`] demonstrator.
//!
//!   repro serve jobs=3 workers=4 d=8192 rounds=10 algo=ring
//!
//! One [`MuxTransport::loopback_mesh`] is built with `jobs` logical
//! channels; each job is an independent [`Session`] (its own model,
//! sources, compressor state, and seed) whose collective runs over its
//! own channel of the shared sockets. The server interleaves their
//! rounds per `server.schedule`; because channel framing is stripped
//! below the round/seq guard, every job's result is bit-identical to a
//! solo run (pinned in `tests/serve.rs`).
//!
//! Knobs (`key=value`; validated against `api::keys::SERVE`):
//!
//! | key | default | meaning |
//! |-----|---------|---------|
//! | `jobs` | 2 | concurrent jobs = logical channels on the one mesh |
//! | `workers`, `d`, `rounds`, `lr`, `seed` | 4, 2^13, 10, 0.2, 100 | per-job shape (job j trains on sources seeded `seed + 1000·j`) |
//! | `algo` | `ring` | staged collective (`ring`, `halving`, `two-level`) |
//! | `pipeline` | `barrier` | `barrier` or `streamed` |
//! | `hierarchy.group_size` | 4 | ranks per group for `algo=two-level` |
//! | `net.timeout_ms` | 30000 (env `INTSGD_NET_TIMEOUT_MS`) | per-logical-op deadline |
//! | `net.retries` | 8 | retried attempts per collective |
//! | `net.mux.queue_frames` | 64 | per-(channel, peer) send-queue bound; a full queue is typed backpressure, counted in `intsgd_net_backpressure_events_total` |
//! | `server.schedule` | `rr` | `rr` (weighted round-robin) or `jitter` (seeded uniform pick) |
//! | `server.jitter_seed` | `seed` | scheduler seed for `server.schedule=jitter` |
//! | `telemetry.trace_path` | off | write the phase-span journal as a Chrome trace at the end |
//! | `telemetry.listen` | off | serve one Prometheus endpoint for all jobs |
//! | `serve_ms` | 0 | keep the metrics endpoint up this long after the run (needs `telemetry.listen`) |

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::api::{
    Backend, CompressorSpec, JobSchedule, ModelSpec, Session, SessionServer,
};
use crate::config::Config;
use crate::net::MuxTransport;
use crate::telemetry;

use super::net_driver::{pipeline_knob, quad_factories, staged_algo};

pub fn run(cfg: &Config) -> Result<()> {
    let jobs = cfg.parsed_or("jobs", 2usize)?;
    let n = cfg.parsed_or("workers", 4usize)?;
    let d = cfg.parsed_or("d", 1usize << 13)?;
    let rounds = cfg.parsed_or("rounds", 10usize)?;
    let lr = cfg.parsed_or("lr", 0.2f32)?;
    let seed = cfg.parsed_or("seed", 100u64)?;
    let algo = staged_algo(cfg)?;
    let pipeline = pipeline_knob(cfg, "barrier")?;
    let queue_frames =
        cfg.parsed_or("net.mux.queue_frames", crate::net::poll::DEFAULT_QUEUE_FRAMES)?;
    let timeout = Duration::from_millis(cfg.parsed_or(
        "net.timeout_ms",
        crate::net::default_io_timeout().as_millis() as u64,
    )?);
    let retries = cfg.parsed_or("net.retries", 8usize)?;
    let schedule = match cfg.str_or("server.schedule", "rr") {
        "rr" | "round-robin" => JobSchedule::RoundRobin,
        "jitter" => JobSchedule::Jitter {
            seed: cfg.parsed_or("server.jitter_seed", seed)?,
        },
        other => return Err(anyhow!("unknown server.schedule {other:?} (rr|jitter)")),
    };
    if jobs == 0 {
        return Err(anyhow!("jobs must be at least 1"));
    }

    // One metrics endpoint and one trace journal for the whole server —
    // jobs share the process-global registry (distinguished where the
    // instruments carry a channel label).
    let metrics = match cfg.get("telemetry.listen") {
        Some(addr) => Some(
            telemetry::MetricsServer::bind(addr)
                .map_err(|e| anyhow!("telemetry.listen {addr}: {e}"))?,
        ),
        None => None,
    };
    if cfg.get("telemetry.trace_path").is_some() {
        telemetry::journal::enable(telemetry::journal::DEFAULT_CAPACITY);
    }

    // The one shared physical mesh: `jobs` channels over n(n-1)/2 sockets.
    let mut mesh = MuxTransport::loopback_mesh_with(n, jobs, queue_frames)?;
    let mut server = SessionServer::new(schedule);
    let mut handles = Vec::new();
    for j in 0..jobs {
        let endpoints = mesh.remove(0); // channel j, rank-ordered
        let session = Session::builder()
            .world(n)
            .model(ModelSpec::flat(d))
            .sources(quad_factories(n, d, seed + 1000 * j as u64, 0.01))
            .compressor(CompressorSpec::parse("intsgd_random8")?)
            .seed(seed ^ 0x5EED ^ j as u64)
            .lr(lr)
            .backend(Backend::Mux { algo })
            .mux_endpoints(endpoints)
            .pipeline(pipeline)
            .net_timeout(timeout)
            .net_retries(retries)
            .build()?;
        handles.push(server.add_job(format!("job-{j}"), session, rounds)?);
    }

    println!(
        "serve: {jobs} jobs x {rounds} rounds over one {n}-rank mux mesh \
         ({algo:?}, {pipeline:?}, {schedule:?})"
    );
    if let Some(m) = &metrics {
        println!("  metrics: http://{}/metrics", m.addr());
    }
    server.run_to_completion()?;

    for &h in &handles {
        let records = server.session(h).records();
        let first = records.first().map(|r| r.train_loss).unwrap_or(f64::NAN);
        let last = records.last().map(|r| r.train_loss).unwrap_or(f64::NAN);
        let stats = server
            .session(h)
            .wire_stats()
            .ok_or_else(|| anyhow!("mux job without wire stats"))?;
        println!(
            "  {}: train loss {first:.4} -> {last:.4} ({} collectives, \
             last wire {:?})",
            server.name(h),
            stats.collectives,
            stats.last_wire,
        );
        if last.is_nan() || last >= first {
            return Err(anyhow!(
                "{} made no progress over the shared mesh: {first} -> {last}",
                server.name(h)
            ));
        }
    }

    if let Some(path) = cfg.get("telemetry.trace_path") {
        telemetry::write_trace(path)?;
        println!("  phase spans -> {path}");
    }
    let serve_ms = cfg.parsed_or("serve_ms", 0u64)?;
    if serve_ms > 0 {
        if metrics.is_none() {
            return Err(anyhow!("serve_ms needs telemetry.listen=<addr>"));
        }
        println!("  serving metrics for {serve_ms} ms ...");
        std::thread::sleep(Duration::from_millis(serve_ms));
    }
    server.shutdown();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_runs_two_jobs_over_one_mesh() {
        let mut cfg = Config::new();
        for kv in ["jobs=2", "workers=3", "d=256", "rounds=6"] {
            cfg.set_kv(kv).unwrap();
        }
        run(&cfg).expect("two-job serve");
    }

    #[test]
    fn serve_jitter_schedule_runs() {
        let mut cfg = Config::new();
        for kv in [
            "jobs=2",
            "workers=2",
            "d=128",
            "rounds=4",
            "server.schedule=jitter",
            "server.jitter_seed=7",
        ] {
            cfg.set_kv(kv).unwrap();
        }
        run(&cfg).expect("jitter serve");
    }

    #[test]
    fn rejects_bad_knobs() {
        let mut cfg = Config::new();
        cfg.set_kv("server.schedule=lottery").unwrap();
        assert!(run(&cfg).unwrap_err().to_string().contains("rr|jitter"));
        let mut cfg = Config::new();
        cfg.set_kv("jobs=0").unwrap();
        assert!(run(&cfg).unwrap_err().to_string().contains("at least 1"));
        let mut cfg = Config::new();
        for kv in ["jobs=1", "workers=2", "d=64", "rounds=2", "serve_ms=5"] {
            cfg.set_kv(kv).unwrap();
        }
        assert!(run(&cfg).unwrap_err().to_string().contains("telemetry.listen"));
    }
}
