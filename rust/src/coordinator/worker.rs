//! Worker pool: one OS thread per simulated device.
//!
//! PJRT clients are `Rc`-backed (not `Send`), so each worker *constructs*
//! its gradient source inside its own thread from a `Send` factory — the
//! same pattern a real multi-process launcher would use (each rank opens
//! its own device).
//!
//! Besides gradient rounds, the pool executes two phases of the
//! compression engine:
//!
//! - **encode**: rank i's encoder runs on worker thread i, in place over
//!   the leader's gradient slice — the reported encode cost is a true
//!   straggler max instead of a leader-thread serialization;
//! - **integer reduce**: the rank messages are summed coordinate-chunk by
//!   coordinate-chunk across the worker threads, each chunk folding the
//!   ranks in rank order (bit-identical to the serial fold — integer
//!   addition is exactly associative).
//!
//! **Plumbing.** Each worker owns a pair of fixed single-slot mailboxes
//! (job in, reply out) built on `Mutex<Option<T>>` + `Condvar` — unlike an
//! mpsc channel, posting a message writes a slot instead of allocating a
//! list node, which keeps steady-state engine rounds allocation-free
//! (`tests/zero_alloc.rs`). The protocol is strictly fan-out/fan-in: the
//! leader posts at most one job per worker, then blocks until it has
//! collected every reply. That blocking discipline is also what makes the
//! borrowed-data jobs sound: encode and reduce jobs carry raw views into
//! leader-owned state (gradients, encoders, the shared plan, disjoint
//! output chunks), and the leader provably does not move, mutate, or free
//! any of it until all acks are in. Worker panics are caught and reported
//! as a reply, so a failing encoder surfaces as a leader panic instead of
//! a deadlocked mailbox.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::compress::engine::{PassPlan, RankEncoder};
use crate::compress::intvec::IntVec;

/// What a worker computes each round: the local stochastic gradient.
pub trait GradientSource {
    fn dim(&self) -> usize;

    /// (local loss, flattened gradient) at `params` for round `round`.
    fn grad(&mut self, params: &[f32], round: usize) -> (f32, Vec<f32>);
}

/// One-message mailbox. `put` blocks while the slot is full (never, under
/// the fan-out/fan-in protocol), `take` blocks until a message arrives.
struct Slot<T> {
    inner: Mutex<Option<T>>,
    cv: Condvar,
}

impl<T> Slot<T> {
    fn new() -> Self {
        Slot { inner: Mutex::new(None), cv: Condvar::new() }
    }

    fn put(&self, value: T) {
        let mut guard = self.inner.lock().expect("mailbox poisoned");
        while guard.is_some() {
            guard = self.cv.wait(guard).expect("mailbox poisoned");
        }
        *guard = Some(value);
        self.cv.notify_all();
    }

    fn take(&self) -> T {
        let mut guard = self.inner.lock().expect("mailbox poisoned");
        loop {
            if let Some(value) = guard.take() {
                self.cv.notify_all();
                return value;
            }
            guard = self.cv.wait(guard).expect("mailbox poisoned");
        }
    }
}

/// Borrowed view of one rank's encoder, valid for the duration of one
/// blocking round (see the module docs for the soundness argument).
#[derive(Clone, Copy)]
struct EncoderMut(*mut Box<dyn RankEncoder>);
// SAFETY: points at leader-owned memory that only the receiving worker
// touches until the leader has collected that worker's ack.
unsafe impl Send for EncoderMut {}

/// Borrowed view of the full encoder slice (shared, read-only).
#[derive(Clone, Copy)]
struct EncodersRef {
    ptr: *const Box<dyn RankEncoder>,
    len: usize,
}
// SAFETY: shared read-only view of leader-owned memory, live until every
// worker acks; `RankEncoder: Sync` makes the concurrent reads legal.
unsafe impl Send for EncodersRef {}

/// Borrowed view of one rank's gradient (shared, read-only).
#[derive(Clone, Copy)]
struct GradRef {
    ptr: *const f32,
    len: usize,
}
// SAFETY: as EncodersRef.
unsafe impl Send for GradRef {}

/// Borrowed view of the pass plan (shared, read-only).
#[derive(Clone, Copy)]
struct PlanRef(*const PassPlan);
// SAFETY: as EncodersRef.
unsafe impl Send for PlanRef {}

/// Borrowed view of one worker's exclusive output chunk.
#[derive(Clone, Copy)]
struct SumChunk {
    ptr: *mut i64,
    len: usize,
    /// Coordinate offset of the chunk within the messages.
    lo: usize,
}
// SAFETY: chunks handed to different workers are disjoint, and the leader
// does not touch the buffer until every worker acks.
unsafe impl Send for SumChunk {}

/// Borrowed view of one rank's exclusive block slot (streamed encode).
#[derive(Clone, Copy)]
struct BlockSlotMut(*mut IntVec);
// SAFETY: slots handed to different workers are distinct elements of a
// leader-owned buffer that only the receiving worker touches until the
// leader has collected that worker's ack (the streamed driver reads the
// OTHER parity's slots in the meantime — a different `Vec` entirely).
unsafe impl Send for BlockSlotMut {}

enum ToWorker {
    Round { params: Arc<Vec<f32>>, round: usize },
    Encode { enc: EncoderMut, grad: GradRef, plan: PlanRef },
    EncodeBlock { enc: EncoderMut, grad: GradRef, plan: PlanRef, block: usize, out: BlockSlotMut },
    SumInts { encs: EncodersRef, chunk: SumChunk },
    Stop,
}

enum FromWorker {
    Grad { loss: f32, grad: Vec<f32>, seconds: f64 },
    Encoded { seconds: f64 },
    Summed,
    Panicked(String),
}

struct WorkerLink {
    job: Arc<Slot<ToWorker>>,
    reply: Arc<Slot<FromWorker>>,
}

pub struct WorkerPool {
    links: Vec<WorkerLink>,
    handles: Vec<JoinHandle<()>>,
}

/// Below this coordinate count the fan-out overhead of a chunked reduce
/// exceeds the fold itself; the leader sums inline instead. Chunking is
/// a pure execution-strategy choice — results are bit-identical.
const PARALLEL_SUM_MIN_D: usize = 1 << 15;

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

/// Execute one job on the worker thread.
// Telemetry timing: the Instant reads here measure phase seconds for the
// straggler report and never feed round arithmetic (clippy.toml).
#[allow(clippy::disallowed_methods)]
fn run_job(source: &mut dyn GradientSource, job: ToWorker) -> FromWorker {
    match job {
        ToWorker::Round { params, round } => {
            let t0 = Instant::now();
            let (loss, grad) = source.grad(&params, round);
            FromWorker::Grad { loss, grad, seconds: t0.elapsed().as_secs_f64() }
        }
        ToWorker::Encode { enc, grad, plan } => {
            // SAFETY: leader-owned borrows, live until this worker's ack
            // is collected; the encoder pointer is exclusive to this
            // worker (module docs).
            let enc = unsafe { &mut *enc.0 };
            let grad = unsafe { std::slice::from_raw_parts(grad.ptr, grad.len) };
            let plan = unsafe { &*plan.0 };
            let t0 = Instant::now();
            enc.encode(grad, plan);
            FromWorker::Encoded { seconds: t0.elapsed().as_secs_f64() }
        }
        ToWorker::EncodeBlock { enc, grad, plan, block, out } => {
            // SAFETY: as Encode, plus the block slot is exclusive to this
            // worker until its ack is collected (see BlockSlotMut).
            let enc = unsafe { &mut *enc.0 };
            let grad = unsafe { std::slice::from_raw_parts(grad.ptr, grad.len) };
            let plan = unsafe { &*plan.0 };
            let out = unsafe { &mut *out.0 };
            let t0 = Instant::now();
            let ok = enc.encode_block(grad, plan, block, out);
            assert!(ok, "encoder does not support per-block encode (streams() lied)");
            FromWorker::Encoded { seconds: t0.elapsed().as_secs_f64() }
        }
        ToWorker::SumInts { encs, chunk } => {
            // SAFETY: shared read-only encoder slice; the output chunk is
            // exclusive to this worker and disjoint from every other
            // worker's chunk (module docs).
            let encs = unsafe { std::slice::from_raw_parts(encs.ptr, encs.len) };
            let out = unsafe { std::slice::from_raw_parts_mut(chunk.ptr, chunk.len) };
            for enc in encs {
                enc.message().as_ints().add_range_to(chunk.lo, out);
            }
            FromWorker::Summed
        }
        ToWorker::Stop => unreachable!("Stop is handled by the worker loop"),
    }
}

impl WorkerPool {
    /// Spawn one thread per factory; each factory builds that rank's
    /// gradient source in-thread.
    pub fn spawn(
        factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>>,
    ) -> Self {
        let mut links = Vec::new();
        let mut handles = Vec::new();
        for (rank, factory) in factories.into_iter().enumerate() {
            let job = Arc::new(Slot::new());
            let reply = Arc::new(Slot::new());
            let job_w = Arc::clone(&job);
            let reply_w = Arc::clone(&reply);
            let handle = std::thread::Builder::new()
                .name(format!("worker-{rank}"))
                .spawn(move || {
                    // A factory panic must not kill the thread before the
                    // job loop: a dead mailbox would hang the leader's
                    // fan-in forever. Keep serving the protocol, answering
                    // every job with the construction failure instead.
                    let mut source = catch_unwind(AssertUnwindSafe(factory))
                        .map_err(|p| format!(
                            "gradient source construction panicked: {}",
                            panic_text(&*p)
                        ));
                    loop {
                        let msg = job_w.take();
                        if matches!(msg, ToWorker::Stop) {
                            break;
                        }
                        let reply = match &mut source {
                            Ok(src) => catch_unwind(AssertUnwindSafe(|| {
                                run_job(src.as_mut(), msg)
                            }))
                            .unwrap_or_else(|p| FromWorker::Panicked(panic_text(&*p))),
                            Err(why) => FromWorker::Panicked(why.clone()),
                        };
                        reply_w.put(reply);
                    }
                })
                .expect("spawn worker thread");
            links.push(WorkerLink { job, reply });
            handles.push(handle);
        }
        WorkerPool { links, handles }
    }

    /// A pool whose workers only serve the compression phases (benchmarks
    /// and parity tests that feed gradients from outside).
    pub fn for_encode(n: usize) -> Self {
        struct Null;
        impl GradientSource for Null {
            fn dim(&self) -> usize {
                0
            }
            fn grad(&mut self, _params: &[f32], _round: usize) -> (f32, Vec<f32>) {
                (0.0, Vec::new())
            }
        }
        let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> = (0..n)
            .map(|_| {
                let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                    Box::new(|| Box::new(Null) as Box<dyn GradientSource>);
                f
            })
            .collect();
        Self::spawn(factories)
    }

    pub fn workers(&self) -> usize {
        self.links.len()
    }

    /// Remove one worker from the pool (failover: its rank died on the
    /// fabric, so its gradient source leaves the job). The worker thread
    /// itself is healthy — only its transport endpoint is gone — so it is
    /// stopped and joined cleanly; surviving workers keep their ranks'
    /// order (rank i > `rank` becomes rank i - 1, matching the reducer's
    /// survivor re-keying and the engine's encoder removal).
    pub fn remove_worker(&mut self, rank: usize) {
        assert!(rank < self.links.len(), "no worker {rank} to remove");
        assert!(self.links.len() > 1, "cannot remove the last worker");
        let link = self.links.remove(rank);
        link.job.put(ToWorker::Stop);
        let handle = self.handles.remove(rank);
        let _ = handle.join();
    }

    /// Broadcast params, wait for all gradients. Returns per-rank grads &
    /// losses plus the straggler (max) compute time — what a synchronous
    /// round actually costs.
    pub fn compute_round(
        &mut self,
        params: &[f32],
        round: usize,
    ) -> (Vec<Vec<f32>>, Vec<f32>, f64) {
        let shared = Arc::new(params.to_vec());
        for link in &self.links {
            link.job.put(ToWorker::Round { params: Arc::clone(&shared), round });
        }
        let n = self.workers();
        let mut grads = Vec::with_capacity(n);
        let mut losses = Vec::with_capacity(n);
        let mut max_seconds = 0.0f64;
        let mut failed: Option<(usize, String)> = None;
        for (rank, link) in self.links.iter().enumerate() {
            match link.reply.take() {
                FromWorker::Grad { loss, grad, seconds } => {
                    losses.push(loss);
                    max_seconds = max_seconds.max(seconds);
                    grads.push(grad);
                }
                FromWorker::Panicked(msg) => {
                    if failed.is_none() {
                        failed = Some((rank, msg));
                    }
                    grads.push(Vec::new());
                    losses.push(0.0);
                }
                _ => panic!("unexpected encode/reduce reply during compute phase"),
            }
        }
        if let Some((rank, msg)) = failed {
            panic!("worker result unavailable: rank {rank} compute panicked: {msg}");
        }
        (grads, losses, max_seconds)
    }

    /// Run one encode pass: rank i's encoder executes on worker thread i,
    /// in place, reading the leader's gradient slice and the shared plan.
    /// Returns the straggler (max) encode time across ranks. Blocks until
    /// every worker has acked (the soundness contract of the borrowed
    /// views — see the module docs).
    pub fn encode_round(
        &mut self,
        plan: &PassPlan,
        encoders: &mut [Box<dyn RankEncoder>],
        grads: &[Vec<f32>],
    ) -> f64 {
        let n = self.workers();
        assert_eq!(encoders.len(), n, "one encoder per worker");
        assert_eq!(grads.len(), n, "one gradient per worker");
        let plan_ref = PlanRef(plan as *const PassPlan);
        // iter_mut hands out disjoint element borrows, so each worker's
        // raw encoder pointer derives from its own borrow (no slice-wide
        // re-borrow between iterations)
        for ((enc_slot, grad), link) in
            encoders.iter_mut().zip(grads.iter()).zip(self.links.iter())
        {
            let enc = EncoderMut(enc_slot as *mut Box<dyn RankEncoder>);
            let grad = GradRef { ptr: grad.as_ptr(), len: grad.len() };
            link.job.put(ToWorker::Encode { enc, grad, plan: plan_ref });
        }
        let mut straggler = 0.0f64;
        let mut failed: Option<(usize, String)> = None;
        // Collect EVERY ack before reporting a failure: the borrowed views
        // must not outlive this call while a worker still holds them.
        for (rank, link) in self.links.iter().enumerate() {
            match link.reply.take() {
                FromWorker::Encoded { seconds } => straggler = straggler.max(seconds),
                FromWorker::Panicked(msg) => {
                    if failed.is_none() {
                        failed = Some((rank, msg));
                    }
                }
                _ => panic!("unexpected gradient reply during encode phase"),
            }
        }
        if let Some((rank, msg)) = failed {
            panic!("worker result unavailable: encode rank {rank} panicked: {msg}");
        }
        straggler
    }

    /// Post one per-block encode job per worker WITHOUT collecting the
    /// acks — the fan-out half of the streamed driver's double buffer:
    /// rank i's encoder fills its block slot on worker thread i while the
    /// leader runs the previous block's collective. Every post MUST be
    /// paired with a [`WorkerPool::collect_encode_block`] before the
    /// leader touches `encoders`, `grads`, the plan, or the `slots`
    /// parity handed out here — the same borrowed-views contract as
    /// [`WorkerPool::encode_round`], split in two.
    pub fn post_encode_block(
        &mut self,
        plan: &PassPlan,
        block: usize,
        encoders: &mut [Box<dyn RankEncoder>],
        grads: &[Vec<f32>],
        slots: &mut [IntVec],
    ) {
        let n = self.workers();
        assert_eq!(encoders.len(), n, "one encoder per worker");
        assert_eq!(grads.len(), n, "one gradient per worker");
        assert_eq!(slots.len(), n, "one block slot per worker");
        let plan_ref = PlanRef(plan as *const PassPlan);
        for (((enc_slot, grad), out), link) in encoders
            .iter_mut()
            .zip(grads.iter())
            .zip(slots.iter_mut())
            .zip(self.links.iter())
        {
            let enc = EncoderMut(enc_slot as *mut Box<dyn RankEncoder>);
            let grad = GradRef { ptr: grad.as_ptr(), len: grad.len() };
            let out = BlockSlotMut(out as *mut IntVec);
            link.job.put(ToWorker::EncodeBlock { enc, grad, plan: plan_ref, block, out });
        }
    }

    /// The fan-in half of [`WorkerPool::post_encode_block`]: block until
    /// every worker acked its block encode, returning the straggler (max)
    /// encode time. Collects EVERY ack before surfacing a failure — the
    /// borrowed views must be dead before this call returns, panic or
    /// not.
    pub fn collect_encode_block(&mut self) -> f64 {
        let mut straggler = 0.0f64;
        let mut failed: Option<(usize, String)> = None;
        for (rank, link) in self.links.iter().enumerate() {
            match link.reply.take() {
                FromWorker::Encoded { seconds } => straggler = straggler.max(seconds),
                FromWorker::Panicked(msg) => {
                    if failed.is_none() {
                        failed = Some((rank, msg));
                    }
                }
                _ => panic!("unexpected gradient reply during encode phase"),
            }
        }
        if let Some((rank, msg)) = failed {
            panic!("worker result unavailable: encode rank {rank} panicked: {msg}");
        }
        straggler
    }

    /// Sum the encoders' integer messages into `out` (already zeroed by
    /// the caller), coordinate-chunked across the worker threads; each
    /// chunk folds the ranks in rank order, so the result is bit-identical
    /// to a serial fold. Small reductions run inline on the leader.
    pub fn sum_ints_round(&mut self, encs: &[Box<dyn RankEncoder>], out: &mut [i64]) {
        let d = out.len();
        let n = self.workers();
        if n <= 1 || d < PARALLEL_SUM_MIN_D {
            for enc in encs {
                enc.message().as_ints().add_range_to(0, out);
            }
            return;
        }
        let encs_ref = EncodersRef { ptr: encs.as_ptr(), len: encs.len() };
        let base = out.as_mut_ptr();
        for (w, link) in self.links.iter().enumerate() {
            let lo = w * d / n;
            let hi = (w + 1) * d / n;
            // SAFETY: [lo, hi) ranges tile [0, d) disjointly across workers.
            let chunk = SumChunk { ptr: unsafe { base.add(lo) }, len: hi - lo, lo };
            link.job.put(ToWorker::SumInts { encs: encs_ref, chunk });
        }
        let mut failed: Option<(usize, String)> = None;
        for (rank, link) in self.links.iter().enumerate() {
            match link.reply.take() {
                FromWorker::Summed => {}
                FromWorker::Panicked(msg) => {
                    if failed.is_none() {
                        failed = Some((rank, msg));
                    }
                }
                _ => panic!("unexpected reply during reduce phase"),
            }
        }
        if let Some((rank, msg)) = failed {
            panic!("worker result unavailable: reduce chunk {rank} panicked: {msg}");
        }
    }

    /// Stop all workers and join their threads.
    pub fn shutdown(&mut self) {
        for link in &self.links {
            link.job.put(ToWorker::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.links.clear();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::engine::Message;
    use crate::compress::intvec::{IntVec, Lanes};

    struct Echo {
        rank: usize,
        d: usize,
    }

    impl GradientSource for Echo {
        fn dim(&self) -> usize {
            self.d
        }

        fn grad(&mut self, params: &[f32], round: usize) -> (f32, Vec<f32>) {
            // grad[j] = rank + round + params[j], loss = rank
            let g = params
                .iter()
                .map(|&p| self.rank as f32 + round as f32 + p)
                .collect();
            (self.rank as f32, g)
        }
    }

    fn echo_pool(n: usize, d: usize) -> WorkerPool {
        let factories: Vec<_> = (0..n)
            .map(|rank| {
                let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                    Box::new(move || Box::new(Echo { rank, d }) as _);
                f
            })
            .collect();
        WorkerPool::spawn(factories)
    }

    #[test]
    fn results_arrive_in_rank_order() {
        let mut pool = echo_pool(5, 3);
        let (grads, losses, secs) = pool.compute_round(&[1.0, 2.0, 3.0], 7);
        pool.shutdown();
        assert!(secs >= 0.0);
        for rank in 0..5 {
            assert_eq!(losses[rank], rank as f32);
            assert_eq!(
                grads[rank],
                vec![rank as f32 + 8.0, rank as f32 + 9.0, rank as f32 + 10.0]
            );
        }
    }

    #[test]
    fn multiple_rounds() {
        let mut pool = echo_pool(2, 1);
        for round in 0..10 {
            let (grads, _, _) = pool.compute_round(&[0.0], round);
            assert_eq!(grads[0][0], round as f32);
            assert_eq!(grads[1][0], 1.0 + round as f32);
        }
        pool.shutdown();
    }

    #[test]
    fn remove_worker_shrinks_the_pool_cleanly() {
        let mut pool = echo_pool(3, 1);
        let (grads, _, _) = pool.compute_round(&[0.0], 0);
        assert_eq!(grads.len(), 3);
        pool.remove_worker(2);
        assert_eq!(pool.workers(), 2);
        // survivors keep computing in rank order
        let (grads, losses, _) = pool.compute_round(&[0.0], 1);
        assert_eq!(grads.len(), 2);
        assert_eq!(losses, vec![0.0, 1.0]);
        pool.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_drop_safe() {
        let mut pool = echo_pool(3, 1);
        pool.shutdown();
        pool.shutdown();
        drop(pool);
    }

    /// An encoder that scales its gradient by its rank — enough to prove
    /// the encode phase runs on the right thread over the right data and
    /// that the in-place encoder state survives.
    struct ScaleByRank {
        rank: usize,
        msg: Message,
    }

    impl RankEncoder for ScaleByRank {
        fn encode(&mut self, grad: &[f32], _plan: &PassPlan) {
            let out = self.msg.dense_mut();
            out.clear();
            out.extend(grad.iter().map(|&g| g * self.rank as f32));
        }

        fn message(&self) -> &Message {
            &self.msg
        }
    }

    #[test]
    fn encode_round_runs_each_rank_in_place() {
        let n = 4;
        let mut pool = WorkerPool::for_encode(n);
        let plan = PassPlan::Plain;
        let mut encoders: Vec<Box<dyn RankEncoder>> = (0..n)
            .map(|rank| {
                Box::new(ScaleByRank { rank, msg: Message::Empty }) as Box<dyn RankEncoder>
            })
            .collect();
        for round in 0..3 {
            let grads: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0 + round as f32; 2]).collect();
            let straggler = pool.encode_round(&plan, &mut encoders, &grads);
            assert!(straggler >= 0.0);
            for (rank, enc) in encoders.iter().enumerate() {
                let expect = (1.0 + round as f32) * rank as f32;
                assert_eq!(enc.message().as_dense(), &[expect, expect]);
            }
        }
        pool.shutdown();
    }

    /// An encoder whose message is a fixed integer vector (for the
    /// chunked-reduce test).
    struct FixedInts {
        msg: Message,
    }

    impl RankEncoder for FixedInts {
        fn encode(&mut self, _grad: &[f32], _plan: &PassPlan) {}

        fn message(&self) -> &Message {
            &self.msg
        }
    }

    #[test]
    fn chunked_sum_matches_serial_fold() {
        let n = 3;
        // force the parallel path despite a small-ish d by using a size
        // above the threshold
        let d = PARALLEL_SUM_MIN_D + 17;
        let encoders: Vec<Box<dyn RankEncoder>> = (0..n)
            .map(|rank| {
                let vals: Vec<i64> =
                    (0..d).map(|j| ((j as i64 % 11) - 5) * (rank as i64 + 1)).collect();
                Box::new(FixedInts {
                    msg: Message::Ints(IntVec::from_i64(&vals, Lanes::I32)),
                }) as Box<dyn RankEncoder>
            })
            .collect();
        let mut serial = vec![0i64; d];
        for enc in &encoders {
            enc.message().as_ints().add_range_to(0, &mut serial);
        }
        let mut pool = WorkerPool::for_encode(n);
        let mut chunked = vec![0i64; d];
        pool.sum_ints_round(&encoders, &mut chunked);
        pool.shutdown();
        assert_eq!(serial, chunked);
    }

    #[test]
    fn compute_and_encode_interleave() {
        let mut pool = echo_pool(2, 2);
        let (grads, _, _) = pool.compute_round(&[0.0, 0.0], 1);
        let plan = PassPlan::Plain;
        let mut encoders: Vec<Box<dyn RankEncoder>> = (0..2)
            .map(|rank| {
                Box::new(ScaleByRank { rank, msg: Message::Empty }) as Box<dyn RankEncoder>
            })
            .collect();
        let _ = pool.encode_round(&plan, &mut encoders, &grads);
        // rank 1's gradient was [2.0, 2.0]; scaled by rank 1 stays [2.0, 2.0]
        assert_eq!(encoders[1].message().as_dense(), &[2.0, 2.0]);
        // and the pool still computes gradients afterwards
        let (grads, _, _) = pool.compute_round(&[0.0, 0.0], 2);
        assert_eq!(grads[0], vec![2.0, 2.0]);
        pool.shutdown();
    }

    #[test]
    #[should_panic(expected = "worker result unavailable")]
    fn factory_panic_fails_loudly_instead_of_deadlocking() {
        // The thread must survive a factory panic and answer jobs with the
        // failure — a silently dead mailbox would hang the leader forever.
        let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> =
            vec![Box::new(|| panic!("injected factory failure"))];
        let mut pool = WorkerPool::spawn(factories);
        let _ = pool.compute_round(&[0.0], 0);
    }

    #[test]
    #[should_panic(expected = "worker result unavailable")]
    fn encode_panic_surfaces_on_leader() {
        struct Exploding {
            msg: Message,
        }
        impl RankEncoder for Exploding {
            fn encode(&mut self, _grad: &[f32], _plan: &PassPlan) {
                panic!("injected encode failure");
            }
            fn message(&self) -> &Message {
                &self.msg
            }
        }
        let mut pool = WorkerPool::for_encode(1);
        let mut encoders: Vec<Box<dyn RankEncoder>> =
            vec![Box::new(Exploding { msg: Message::Empty })];
        let grads = vec![vec![0.0f32; 4]];
        let _ = pool.encode_round(&PassPlan::Plain, &mut encoders, &grads);
    }
}
