//! Worker pool: one OS thread per simulated device.
//!
//! PJRT clients are `Rc`-backed (not `Send`), so each worker *constructs*
//! its gradient source inside its own thread from a `Send` factory — the
//! same pattern a real multi-process launcher would use (each rank opens
//! its own device).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// What a worker computes each round: the local stochastic gradient.
pub trait GradientSource {
    fn dim(&self) -> usize;

    /// (local loss, flattened gradient) at `params` for round `round`.
    fn grad(&mut self, params: &[f32], round: usize) -> (f32, Vec<f32>);
}

enum ToWorker {
    Round { params: Arc<Vec<f32>>, round: usize },
    Stop,
}

struct FromWorker {
    rank: usize,
    loss: f32,
    grad: Vec<f32>,
    seconds: f64,
}

pub struct WorkerPool {
    senders: Vec<Sender<ToWorker>>,
    receiver: Receiver<FromWorker>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn one thread per factory; each factory builds that rank's
    /// gradient source in-thread.
    pub fn spawn(
        factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>>,
    ) -> Self {
        let (tx_out, rx_out) = channel::<FromWorker>();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for (rank, factory) in factories.into_iter().enumerate() {
            let (tx_in, rx_in) = channel::<ToWorker>();
            let tx_out = tx_out.clone();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{rank}"))
                .spawn(move || {
                    let mut source = factory();
                    while let Ok(msg) = rx_in.recv() {
                        match msg {
                            ToWorker::Stop => break,
                            ToWorker::Round { params, round } => {
                                let t0 = Instant::now();
                                let (loss, grad) = source.grad(&params, round);
                                let seconds = t0.elapsed().as_secs_f64();
                                if tx_out
                                    .send(FromWorker { rank, loss, grad, seconds })
                                    .is_err()
                                {
                                    break;
                                }
                            }
                        }
                    }
                })
                .expect("spawn worker thread");
            senders.push(tx_in);
            handles.push(handle);
        }
        WorkerPool { senders, receiver: rx_out, handles }
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Broadcast params, wait for all gradients. Returns per-rank grads &
    /// losses plus the straggler (max) compute time — what a synchronous
    /// round actually costs.
    pub fn compute_round(
        &mut self,
        params: &[f32],
        round: usize,
    ) -> (Vec<Vec<f32>>, Vec<f32>, f64) {
        let n = self.workers();
        let shared = Arc::new(params.to_vec());
        for tx in &self.senders {
            tx.send(ToWorker::Round { params: Arc::clone(&shared), round })
                .expect("worker alive");
        }
        let mut grads: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
        let mut losses = vec![0.0f32; n];
        let mut max_seconds = 0.0f64;
        for _ in 0..n {
            let msg = self.receiver.recv().expect("worker result");
            losses[msg.rank] = msg.loss;
            max_seconds = max_seconds.max(msg.seconds);
            grads[msg.rank] = Some(msg.grad);
        }
        (
            grads.into_iter().map(|g| g.expect("all ranks reported")).collect(),
            losses,
            max_seconds,
        )
    }

    /// Stop all workers and join their threads.
    pub fn shutdown(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(ToWorker::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.senders.clear();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo {
        rank: usize,
        d: usize,
    }

    impl GradientSource for Echo {
        fn dim(&self) -> usize {
            self.d
        }

        fn grad(&mut self, params: &[f32], round: usize) -> (f32, Vec<f32>) {
            // grad[j] = rank + round + params[j], loss = rank
            let g = params
                .iter()
                .map(|&p| self.rank as f32 + round as f32 + p)
                .collect();
            (self.rank as f32, g)
        }
    }

    fn echo_pool(n: usize, d: usize) -> WorkerPool {
        let factories: Vec<_> = (0..n)
            .map(|rank| {
                let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                    Box::new(move || Box::new(Echo { rank, d }) as _);
                f
            })
            .collect();
        WorkerPool::spawn(factories)
    }

    #[test]
    fn results_arrive_in_rank_order() {
        let mut pool = echo_pool(5, 3);
        let (grads, losses, secs) = pool.compute_round(&[1.0, 2.0, 3.0], 7);
        pool.shutdown();
        assert!(secs >= 0.0);
        for rank in 0..5 {
            assert_eq!(losses[rank], rank as f32);
            assert_eq!(
                grads[rank],
                vec![rank as f32 + 8.0, rank as f32 + 9.0, rank as f32 + 10.0]
            );
        }
    }

    #[test]
    fn multiple_rounds() {
        let mut pool = echo_pool(2, 1);
        for round in 0..10 {
            let (grads, _, _) = pool.compute_round(&[0.0], round);
            assert_eq!(grads[0][0], round as f32);
            assert_eq!(grads[1][0], 1.0 + round as f32);
        }
        pool.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_drop_safe() {
        let mut pool = echo_pool(3, 1);
        pool.shutdown();
        pool.shutdown();
        drop(pool);
    }
}
