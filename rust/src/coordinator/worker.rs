//! Worker pool: one OS thread per simulated device.
//!
//! PJRT clients are `Rc`-backed (not `Send`), so each worker *constructs*
//! its gradient source inside its own thread from a `Send` factory — the
//! same pattern a real multi-process launcher would use (each rank opens
//! its own device).
//!
//! Besides gradient rounds, the pool executes the compression engine's
//! **encode phase**: the leader ships each rank its encoder (the rank's
//! `Send` compression state), the worker thread encodes its own gradient,
//! and the message travels back. This is what makes the reported encode
//! cost a true straggler max instead of a leader-thread serialization.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::compress::engine::{PassPlan, RankEncoder};

/// What a worker computes each round: the local stochastic gradient.
pub trait GradientSource {
    fn dim(&self) -> usize;

    /// (local loss, flattened gradient) at `params` for round `round`.
    fn grad(&mut self, params: &[f32], round: usize) -> (f32, Vec<f32>);
}

/// One rank's encode job: its encoder, its gradient, and the round plan
/// shared by all ranks. Everything owned moves back in [`EncodeDone`].
pub struct EncodeTask {
    pub rank: usize,
    pub encoder: Box<dyn RankEncoder>,
    pub grad: Vec<f32>,
    pub plan: Arc<PassPlan>,
}

/// The completed encode job: encoder (holding its message) and gradient
/// return to the leader, plus the measured encode wallclock.
pub struct EncodeDone {
    pub rank: usize,
    pub encoder: Box<dyn RankEncoder>,
    pub grad: Vec<f32>,
    pub seconds: f64,
}

enum ToWorker {
    Round { params: Arc<Vec<f32>>, round: usize },
    Encode(EncodeTask),
    Stop,
}

enum FromWorker {
    Grad { rank: usize, loss: f32, grad: Vec<f32>, seconds: f64 },
    Encoded(EncodeDone),
}

pub struct WorkerPool {
    senders: Vec<Sender<ToWorker>>,
    receiver: Receiver<FromWorker>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn one thread per factory; each factory builds that rank's
    /// gradient source in-thread.
    pub fn spawn(
        factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>>,
    ) -> Self {
        let (tx_out, rx_out) = channel::<FromWorker>();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for (rank, factory) in factories.into_iter().enumerate() {
            let (tx_in, rx_in) = channel::<ToWorker>();
            let tx_out = tx_out.clone();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{rank}"))
                .spawn(move || {
                    let mut source = factory();
                    while let Ok(msg) = rx_in.recv() {
                        match msg {
                            ToWorker::Stop => break,
                            ToWorker::Round { params, round } => {
                                let t0 = Instant::now();
                                let (loss, grad) = source.grad(&params, round);
                                let seconds = t0.elapsed().as_secs_f64();
                                if tx_out
                                    .send(FromWorker::Grad { rank, loss, grad, seconds })
                                    .is_err()
                                {
                                    break;
                                }
                            }
                            ToWorker::Encode(mut task) => {
                                let t0 = Instant::now();
                                task.encoder.encode(&task.grad, &task.plan);
                                let seconds = t0.elapsed().as_secs_f64();
                                let done = EncodeDone {
                                    rank: task.rank,
                                    encoder: task.encoder,
                                    grad: task.grad,
                                    seconds,
                                };
                                if tx_out.send(FromWorker::Encoded(done)).is_err() {
                                    break;
                                }
                            }
                        }
                    }
                })
                .expect("spawn worker thread");
            senders.push(tx_in);
            handles.push(handle);
        }
        WorkerPool { senders, receiver: rx_out, handles }
    }

    /// A pool whose workers only serve the encode phase (benchmarks and
    /// parity tests that feed gradients from outside).
    pub fn for_encode(n: usize) -> Self {
        struct Null;
        impl GradientSource for Null {
            fn dim(&self) -> usize {
                0
            }
            fn grad(&mut self, _params: &[f32], _round: usize) -> (f32, Vec<f32>) {
                (0.0, Vec::new())
            }
        }
        let factories: Vec<Box<dyn FnOnce() -> Box<dyn GradientSource> + Send>> = (0..n)
            .map(|_| {
                let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                    Box::new(|| Box::new(Null) as Box<dyn GradientSource>);
                f
            })
            .collect();
        Self::spawn(factories)
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Broadcast params, wait for all gradients. Returns per-rank grads &
    /// losses plus the straggler (max) compute time — what a synchronous
    /// round actually costs.
    pub fn compute_round(
        &mut self,
        params: &[f32],
        round: usize,
    ) -> (Vec<Vec<f32>>, Vec<f32>, f64) {
        let n = self.workers();
        let shared = Arc::new(params.to_vec());
        for tx in &self.senders {
            tx.send(ToWorker::Round { params: Arc::clone(&shared), round })
                .expect("worker alive");
        }
        let mut grads: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
        let mut losses = vec![0.0f32; n];
        let mut max_seconds = 0.0f64;
        for _ in 0..n {
            match self.receiver.recv().expect("worker result") {
                FromWorker::Grad { rank, loss, grad, seconds } => {
                    losses[rank] = loss;
                    max_seconds = max_seconds.max(seconds);
                    grads[rank] = Some(grad);
                }
                FromWorker::Encoded(_) => {
                    panic!("unexpected encode result during compute phase")
                }
            }
        }
        (
            grads.into_iter().map(|g| g.expect("all ranks reported")).collect(),
            losses,
            max_seconds,
        )
    }

    /// Run one encode pass: task i executes on worker thread i. Returns
    /// the completed jobs in rank order plus the straggler (max) encode
    /// time across ranks.
    pub fn encode_round(&mut self, tasks: Vec<EncodeTask>) -> (Vec<EncodeDone>, f64) {
        let n = tasks.len();
        assert_eq!(n, self.workers(), "one encode task per worker");
        for task in tasks {
            let rank = task.rank;
            self.senders[rank]
                .send(ToWorker::Encode(task))
                .expect("worker alive");
        }
        let mut done: Vec<Option<EncodeDone>> = (0..n).map(|_| None).collect();
        let mut straggler = 0.0f64;
        for _ in 0..n {
            match self.receiver.recv().expect("worker result") {
                FromWorker::Encoded(item) => {
                    straggler = straggler.max(item.seconds);
                    let rank = item.rank;
                    assert!(done[rank].is_none(), "duplicate encode result");
                    done[rank] = Some(item);
                }
                FromWorker::Grad { .. } => {
                    panic!("unexpected gradient during encode phase")
                }
            }
        }
        (
            done.into_iter().map(|d| d.expect("all ranks encoded")).collect(),
            straggler,
        )
    }

    /// Stop all workers and join their threads.
    pub fn shutdown(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(ToWorker::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.senders.clear();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::engine::Message;

    struct Echo {
        rank: usize,
        d: usize,
    }

    impl GradientSource for Echo {
        fn dim(&self) -> usize {
            self.d
        }

        fn grad(&mut self, params: &[f32], round: usize) -> (f32, Vec<f32>) {
            // grad[j] = rank + round + params[j], loss = rank
            let g = params
                .iter()
                .map(|&p| self.rank as f32 + round as f32 + p)
                .collect();
            (self.rank as f32, g)
        }
    }

    fn echo_pool(n: usize, d: usize) -> WorkerPool {
        let factories: Vec<_> = (0..n)
            .map(|rank| {
                let f: Box<dyn FnOnce() -> Box<dyn GradientSource> + Send> =
                    Box::new(move || Box::new(Echo { rank, d }) as _);
                f
            })
            .collect();
        WorkerPool::spawn(factories)
    }

    #[test]
    fn results_arrive_in_rank_order() {
        let mut pool = echo_pool(5, 3);
        let (grads, losses, secs) = pool.compute_round(&[1.0, 2.0, 3.0], 7);
        pool.shutdown();
        assert!(secs >= 0.0);
        for rank in 0..5 {
            assert_eq!(losses[rank], rank as f32);
            assert_eq!(
                grads[rank],
                vec![rank as f32 + 8.0, rank as f32 + 9.0, rank as f32 + 10.0]
            );
        }
    }

    #[test]
    fn multiple_rounds() {
        let mut pool = echo_pool(2, 1);
        for round in 0..10 {
            let (grads, _, _) = pool.compute_round(&[0.0], round);
            assert_eq!(grads[0][0], round as f32);
            assert_eq!(grads[1][0], 1.0 + round as f32);
        }
        pool.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_drop_safe() {
        let mut pool = echo_pool(3, 1);
        pool.shutdown();
        pool.shutdown();
        drop(pool);
    }

    /// An encoder that scales its gradient by its rank — enough to prove
    /// the encode phase runs on the right thread with the right data and
    /// that encoder + gradient round-trip intact.
    struct ScaleByRank {
        rank: usize,
        msg: Message,
    }

    impl RankEncoder for ScaleByRank {
        fn encode(&mut self, grad: &[f32], _plan: &PassPlan) {
            let out = self.msg.dense_mut();
            out.clear();
            out.extend(grad.iter().map(|&g| g * self.rank as f32));
        }

        fn message(&self) -> &Message {
            &self.msg
        }
    }

    #[test]
    fn encode_round_runs_each_rank_and_returns_state() {
        let n = 4;
        let mut pool = WorkerPool::for_encode(n);
        let plan = Arc::new(PassPlan::Plain);
        for round in 0..3 {
            let tasks: Vec<EncodeTask> = (0..n)
                .map(|rank| EncodeTask {
                    rank,
                    encoder: Box::new(ScaleByRank { rank, msg: Message::Empty }),
                    grad: vec![1.0 + round as f32; 2],
                    plan: Arc::clone(&plan),
                })
                .collect();
            let (done, straggler) = pool.encode_round(tasks);
            assert!(straggler >= 0.0);
            for (rank, item) in done.iter().enumerate() {
                assert_eq!(item.rank, rank);
                assert_eq!(item.grad, vec![1.0 + round as f32; 2]);
                let expect = (1.0 + round as f32) * rank as f32;
                assert_eq!(item.encoder.message().as_dense(), &[expect, expect]);
            }
        }
        pool.shutdown();
    }

    #[test]
    fn compute_and_encode_interleave() {
        let mut pool = echo_pool(2, 2);
        let (grads, _, _) = pool.compute_round(&[0.0, 0.0], 1);
        let plan = Arc::new(PassPlan::Plain);
        let tasks: Vec<EncodeTask> = grads
            .into_iter()
            .enumerate()
            .map(|(rank, grad)| EncodeTask {
                rank,
                encoder: Box::new(ScaleByRank { rank, msg: Message::Empty }),
                grad,
                plan: Arc::clone(&plan),
            })
            .collect();
        let (done, _) = pool.encode_round(tasks);
        // rank 1's gradient was [2.0, 2.0]; scaled by rank 1 stays [2.0, 2.0]
        assert_eq!(done[1].encoder.message().as_dense(), &[2.0, 2.0]);
        // and the pool still computes gradients afterwards
        let (grads, _, _) = pool.compute_round(&[0.0, 0.0], 2);
        assert_eq!(grads[0], vec![2.0, 2.0]);
        pool.shutdown();
    }
}
