//! Collective-communication substrate.
//!
//! The paper's systems argument hinges on which collective a compressor can
//! ride on: ring all-reduce (messages summable in-flight), all-gather
//! (everything shipped, decoded at the edge), or SwitchML's in-network
//! aggregation (integer adders in the switch pipeline). This module
//! implements the *data plane* of each primitive faithfully — chunked ring
//! reduce-scatter/all-gather, INA with saturating integer accumulators —
//! so overflow/saturation behaviour is exercised exactly where a real
//! deployment would hit it. The *time* cost of each primitive is modeled
//! separately in `netsim`.

pub mod switch;

pub use switch::InaSwitch;

use crate::compress::intvec::IntVec;

/// Exact integer all-reduce: out[j] = sum_i msgs[i][j], accumulated in i64
/// (never overflows for the wire widths we use: |local| <= 2^31 and n <=
/// a few thousand).
pub fn allreduce_i64(msgs: &[&[i64]], out: &mut Vec<i64>) {
    let n = msgs.len();
    assert!(n > 0);
    let d = msgs[0].len();
    out.clear();
    out.resize(d, 0);
    for m in msgs {
        assert_eq!(m.len(), d, "mismatched message lengths");
        for (o, &x) in out.iter_mut().zip(*m) {
            *o += x;
        }
    }
}

/// Exact integer all-reduce over typed wire buffers: each message's lanes
/// are read at wire width and widened once into the i64 accumulator —
/// an i8 message costs an eighth of the memory traffic of the widened
/// fold above (`benches/bench_collective.rs` measures the difference).
///
/// This is THE serial fold body: the engine's `SerialReducer` delegates
/// here, so the benchmark and the production reduce cannot drift apart.
/// Exact integer arithmetic, so every fold order yields the same bits
/// (the parity guarantee); reuses `out`'s capacity (the zero-allocation
/// guarantee).
///
/// Up to [`crate::simd::SUM_RANKS_MAX`] all-i8 messages take the fused
/// multi-rank kernel ([`crate::simd::sum_ranks_i8`]): one pass over the
/// aggregate with the cross-rank sum held in an i16 intermediate — sound
/// because the i8 wire proves n ≤ 127 ranks of |v| ≤ 127 — instead of
/// one widening read-modify-write sweep per rank. Mixed lanes, wide
/// lanes, or an over-long world fold message-at-a-time as before.
pub fn allreduce_intvec_iter<'a, I>(msgs: I, out: &mut Vec<i64>)
where
    I: IntoIterator<Item = &'a IntVec>,
{
    let mut iter = msgs.into_iter();
    let first = iter.next().expect("at least one message");
    let d = first.len();
    out.clear();
    out.resize(d, 0);
    // Stash candidate i8 messages for the fused fold on the stack (no
    // allocation); anything that disqualifies the batch — a non-i8 lane,
    // more than SUM_RANKS_MAX messages — folds immediately.
    let mut stash: [&IntVec; crate::simd::SUM_RANKS_MAX] = [first; crate::simd::SUM_RANKS_MAX];
    let mut stashed = 0usize;
    let mut fused = true;
    for m in std::iter::once(first).chain(iter) {
        assert_eq!(m.len(), d, "mismatched message lengths");
        if fused && matches!(m, IntVec::I8(_)) && stashed < stash.len() {
            stash[stashed] = m;
            stashed += 1;
            continue;
        }
        if fused {
            // disqualified: drain the stash message-at-a-time
            for s in &stash[..stashed] {
                s.add_range_to(0, out);
            }
            stashed = 0;
            fused = false;
        }
        m.add_range_to(0, out);
    }
    if stashed == 1 {
        stash[0].add_range_to(0, out);
    } else if stashed > 1 {
        let mut views: [&[i8]; crate::simd::SUM_RANKS_MAX] = [&[]; crate::simd::SUM_RANKS_MAX];
        for (v, m) in views.iter_mut().zip(&stash[..stashed]) {
            match m {
                IntVec::I8(b) => *v = b.as_slice(),
                _ => unreachable!("stash holds only i8 messages"),
            }
        }
        crate::simd::sum_ranks_i8(&views[..stashed], out);
    }
}

/// Slice-of-views wrapper around [`allreduce_intvec_iter`].
pub fn allreduce_intvec(msgs: &[&IntVec], out: &mut Vec<i64>) {
    allreduce_intvec_iter(msgs.iter().copied(), out);
}

/// Ring all-reduce over f32 vectors, implemented as the real algorithm:
/// reduce-scatter over n-1 steps on n chunks, then all-gather. Returns the
/// *sum* (callers divide by n). Equivalent to the naive sum up to f32
/// addition-order differences; `tests` pin the tolerance. Takes slices so
/// callers can reduce message views without copying into owned vectors.
pub fn ring_allreduce_f32(workers: &[&[f32]]) -> Vec<f32> {
    let n = workers.len();
    assert!(n > 0);
    let d = workers[0].len();
    if n == 1 {
        return workers[0].to_vec();
    }
    // chunk boundaries: chunk c covers [starts[c], starts[c+1])
    let starts: Vec<usize> = (0..=n).map(|c| c * d / n).collect();
    let mut bufs: Vec<Vec<f32>> = workers.iter().map(|w| w.to_vec()).collect();

    // reduce-scatter: at step s, worker i sends chunk (i - s) to worker i+1
    for s in 0..n - 1 {
        // snapshot of the sending state for this step
        let snapshot: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let c = (i + n - s) % n;
                bufs[i][starts[c]..starts[c + 1]].to_vec()
            })
            .collect();
        for i in 0..n {
            let src = (i + n - 1) % n;
            let c = (src + n - s) % n;
            let (lo, hi) = (starts[c], starts[c + 1]);
            for (dst, &x) in bufs[i][lo..hi].iter_mut().zip(&snapshot[src]) {
                *dst += x;
            }
        }
    }
    // after reduce-scatter, worker i holds the full sum of chunk (i+1) mod n
    let mut out = vec![0.0f32; d];
    for i in 0..n {
        let c = (i + 1) % n;
        out[starts[c]..starts[c + 1]].copy_from_slice(&bufs[i][starts[c]..starts[c + 1]]);
    }
    out
}

/// All-gather: every worker receives every message verbatim, written into
/// the caller's buffer. The copies are the primitive's semantics (every
/// worker owns a replica; byte accounting happens in netsim), but the
/// *allocations* are not: existing slots are reused via `clone_from`, so
/// nested buffers (message vectors, codec byte streams) keep their
/// capacity across rounds — the zero-alloc-hot-path rule of the engine.
/// Note the in-process compressor simulators share memory and skip the
/// replication entirely; this is the edge-replication primitive for
/// callers that materialize per-worker replicas (the old by-value
/// signature forced a fresh `Vec` per call on exactly those paths).
/// `net::staged::ring_allgather_bytes` is its over-the-wire counterpart.
pub fn allgather<T: Clone>(msgs: &[T], out: &mut Vec<T>) {
    out.truncate(msgs.len());
    let reused = out.len();
    for (o, m) in out.iter_mut().zip(msgs) {
        o.clone_from(m);
    }
    for m in &msgs[reused..] {
        out.push(m.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    #[test]
    fn allreduce_i64_sums() {
        let a = vec![1i64, -2, 3];
        let b = vec![10i64, 20, -30];
        let mut out = Vec::new();
        allreduce_i64(&[&a, &b], &mut out);
        assert_eq!(out, vec![11, 18, -27]);
    }

    #[test]
    fn allreduce_intvec_matches_widened_fold() {
        use crate::compress::intvec::Lanes;
        let vals_a = vec![1i64, -2, 3, 100];
        let vals_b = vec![10i64, 20, -30, -100];
        for lanes in [Lanes::I8, Lanes::I32, Lanes::I64] {
            let a = IntVec::from_i64(&vals_a, lanes);
            let b = IntVec::from_i64(&vals_b, lanes);
            let mut typed = Vec::new();
            allreduce_intvec(&[&a, &b], &mut typed);
            let mut widened = Vec::new();
            allreduce_i64(&[&vals_a, &vals_b], &mut widened);
            assert_eq!(typed, widened, "{lanes:?}");
        }
    }

    #[test]
    fn ring_allreduce_matches_naive_sum() {
        prop_check(0x2149, 100, |rng| {
            let n = 1 + rng.usize_below(12);
            let d = 1 + rng.usize_below(300);
            let workers: Vec<Vec<f32>> =
                (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
            let views: Vec<&[f32]> = workers.iter().map(|w| w.as_slice()).collect();
            let ring = ring_allreduce_f32(&views);
            for j in 0..d {
                let naive: f64 =
                    workers.iter().map(|w| w[j] as f64).sum();
                prop_assert!(
                    ((ring[j] as f64) - naive).abs() <= 1e-4 * naive.abs().max(1.0),
                    "coord {j}: ring {} vs naive {naive} (n={n}, d={d})",
                    ring[j]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn ring_allreduce_exact_on_integers() {
        // On integer-valued f32 (IntSGD's case) ring order cannot change
        // the result: f32 addition of small integers is exact.
        let mut rng = Rng::new(3);
        let n = 7;
        let d = 1000;
        let workers: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| (rng.below(255) as i64 - 127) as f32).collect())
            .collect();
        let views: Vec<&[f32]> = workers.iter().map(|w| w.as_slice()).collect();
        let ring = ring_allreduce_f32(&views);
        for j in 0..d {
            let naive: f32 = workers.iter().map(|w| w[j]).sum();
            assert_eq!(ring[j], naive);
        }
    }

    #[test]
    fn allgather_reuses_caller_buffers() {
        let msgs: Vec<Vec<u8>> = vec![vec![1, 2, 3], vec![4], vec![5, 6]];
        let mut out: Vec<Vec<u8>> = vec![Vec::with_capacity(64); 4];
        let caps: Vec<usize> = out.iter().map(|v| v.capacity()).collect();
        allgather(&msgs, &mut out);
        assert_eq!(out, msgs);
        // shrunk to msgs.len(), surviving slots kept their capacity
        for (o, &cap) in out.iter().zip(&caps) {
            assert_eq!(o.capacity(), cap);
        }
        // growing again appends fresh clones
        let more: Vec<Vec<u8>> = (0..5).map(|i| vec![i as u8]).collect();
        allgather(&more, &mut out);
        assert_eq!(out, more);
    }

    #[test]
    fn ring_single_worker_identity() {
        let w = [1.0f32, 2.0, 3.0];
        assert_eq!(ring_allreduce_f32(&[&w]), w.to_vec());
    }

    #[test]
    fn ring_d_smaller_than_n() {
        // degenerate chunking: d < n leaves empty chunks
        let workers: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32, 1.0]).collect();
        let views: Vec<&[f32]> = workers.iter().map(|w| w.as_slice()).collect();
        let out = ring_allreduce_f32(&views);
        assert_eq!(out, vec![10.0, 5.0]);
    }
}
