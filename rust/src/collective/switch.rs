//! SwitchML-style in-network aggregation (INA) simulator.
//!
//! The programmable switch of Sapio et al. (2021) exposes a pipeline of
//! integer adders: workers stream fixed-size chunks of integers; the switch
//! accumulates each slot across workers and multicasts the result. Two
//! properties matter for the algorithms in this repo and are modeled
//! faithfully:
//!
//! 1. The switch only has *integer* ALUs — this is why SwitchML (and
//!    IntSGD) must round to integers before transmission.
//! 2. The accumulators are fixed-width and *saturate*; a bad scaling factor
//!    overflows them, which is exactly the failure mode IntSGD's clipping
//!    and adaptive alpha prevent (paper §1, §5.2).
//!
//! Saturation makes the accumulation order-sensitive, so unlike the exact
//! integer all-reduce this fold is never parallelized: every slot folds
//! the workers in rank order on the caller thread.

use crate::compress::engine::RankMessages;
use crate::compress::intsgd::WireInt;
use crate::compress::intvec::IntVec;

/// Pipeline model of the switch data plane.
#[derive(Clone, Debug)]
pub struct InaSwitch {
    /// Integers aggregated per pipeline slot-batch (SwitchML uses pools of
    /// ~128 slots of 32-bit integers per packet).
    pub chunk_slots: usize,
}

impl Default for InaSwitch {
    fn default() -> Self {
        InaSwitch { chunk_slots: 128 }
    }
}

/// Statistics of one aggregation pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InaStats {
    /// Number of slots whose accumulator saturated.
    pub saturated_slots: usize,
    /// Number of chunks pipelined through the switch.
    pub chunks: usize,
}

impl InaSwitch {
    /// Core fold: slot j accumulates `get(rank, j)` over ranks in order,
    /// saturating at the wire width as it goes. Accessor-based so callers
    /// can aggregate plain slices or typed wire buffers without
    /// materializing `&[i64]` views.
    pub fn aggregate_with<F>(
        &self,
        n: usize,
        d: usize,
        get: F,
        wire: WireInt,
        out: &mut Vec<i64>,
    ) -> InaStats
    where
        F: Fn(usize, usize) -> i64,
    {
        assert!(n > 0);
        out.clear();
        out.resize(d, 0);
        let cap = wire.max_aggregate();
        let mut stats = InaStats::default();
        // process in chunk_slots-sized chunks, as the pipeline would
        let mut lo = 0;
        while lo < d {
            let hi = (lo + self.chunk_slots).min(d);
            stats.chunks += 1;
            for j in lo..hi {
                let mut acc: i64 = 0;
                let mut saturated = false;
                for rank in 0..n {
                    acc += get(rank, j);
                    // fixed-width accumulator saturates as it goes
                    if acc > cap {
                        acc = cap;
                        saturated = true;
                    } else if acc < -cap - 1 {
                        acc = -cap - 1;
                        saturated = true;
                    }
                }
                if saturated {
                    stats.saturated_slots += 1;
                }
                out[j] = acc;
            }
            lo = hi;
        }
        stats
    }

    /// Aggregate per-worker integer vectors with saturating fixed-width
    /// accumulators, writing the result into `out`.
    pub fn aggregate_into(
        &self,
        msgs: &[&[i64]],
        wire: WireInt,
        out: &mut Vec<i64>,
    ) -> InaStats {
        let n = msgs.len();
        assert!(n > 0);
        let d = msgs[0].len();
        for m in msgs {
            assert_eq!(m.len(), d, "mismatched message lengths");
        }
        self.aggregate_with(n, d, |rank, j| msgs[rank][j], wire, out)
    }

    /// Aggregate the ranks' typed integer messages (the engine's reduce
    /// path when `IntSgd::use_switch` is set). The per-rank payload views
    /// are hoisted to typed slices once, so the per-slot inner loop is a
    /// plain indexed read — no virtual call or enum dispatch per element.
    pub fn aggregate_messages(
        &self,
        msgs: &RankMessages,
        wire: WireInt,
        out: &mut Vec<i64>,
    ) -> InaStats {
        let n = msgs.len();
        assert!(n > 0);
        let first = msgs.get(0).as_ints();
        let d = first.len();
        for m in msgs.iter() {
            assert_eq!(m.as_ints().len(), d, "mismatched message lengths");
            assert_eq!(
                m.as_ints().lanes(),
                first.lanes(),
                "mixed lane widths in one pass"
            );
        }
        match first {
            IntVec::I8(_) => {
                let views: Vec<&[i8]> = msgs
                    .iter()
                    .map(|m| match m.as_ints() {
                        IntVec::I8(v) => v.as_slice(),
                        _ => unreachable!("lanes checked above"),
                    })
                    .collect();
                self.aggregate_with(n, d, |rank, j| views[rank][j] as i64, wire, out)
            }
            IntVec::I32(_) => {
                let views: Vec<&[i32]> = msgs
                    .iter()
                    .map(|m| match m.as_ints() {
                        IntVec::I32(v) => v.as_slice(),
                        _ => unreachable!("lanes checked above"),
                    })
                    .collect();
                self.aggregate_with(n, d, |rank, j| views[rank][j] as i64, wire, out)
            }
            IntVec::I64(_) => {
                let views: Vec<&[i64]> = msgs
                    .iter()
                    .map(|m| match m.as_ints() {
                        IntVec::I64(v) => v.as_slice(),
                        _ => unreachable!("lanes checked above"),
                    })
                    .collect();
                self.aggregate_with(n, d, |rank, j| views[rank][j], wire, out)
            }
        }
    }

    /// Convenience wrapper returning the aggregate.
    pub fn aggregate(&self, msgs: &[&[i64]], wire: WireInt) -> (Vec<i64>, InaStats) {
        let mut out = Vec::new();
        let stats = self.aggregate_into(msgs, wire, &mut out);
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn matches_exact_sum_when_in_range() {
        let a = vec![1i64, -2, 3, 100];
        let b = vec![5i64, 5, -5, 27];
        let (out, stats) = InaSwitch::default().aggregate(&[&a, &b], WireInt::Int8);
        assert_eq!(out, vec![6, 3, -2, 127]);
        assert_eq!(stats.saturated_slots, 0);
    }

    #[test]
    fn saturates_on_overflow() {
        let a = vec![100i64, -100];
        let b = vec![100i64, -100];
        let (out, stats) = InaSwitch::default().aggregate(&[&a, &b], WireInt::Int8);
        assert_eq!(out, vec![127, -128]);
        assert_eq!(stats.saturated_slots, 2);
    }

    #[test]
    fn chunk_count() {
        let msgs: Vec<Vec<i64>> = vec![vec![0i64; 1000]];
        let views: Vec<&[i64]> = msgs.iter().map(|v| v.as_slice()).collect();
        let sw = InaSwitch { chunk_slots: 128 };
        let (_, stats) = sw.aggregate(&views, WireInt::Int32);
        assert_eq!(stats.chunks, 8); // ceil(1000/128)
    }

    #[test]
    fn int32_headroom_avoids_saturation_for_clipped_inputs() {
        // Inputs clipped to (2^31-1)/n never saturate the int32 switch.
        prop_check(0x5A7, 50, |rng| {
            let n = 1 + rng.usize_below(64);
            let clip = (i32::MAX as i64) / n as i64;
            let d = 1 + rng.usize_below(200);
            let msgs: Vec<Vec<i64>> = (0..n)
                .map(|_| {
                    (0..d)
                        .map(|_| rng.below(2 * clip as u64 + 1) as i64 - clip)
                        .collect()
                })
                .collect();
            let views: Vec<&[i64]> = msgs.iter().map(|v| v.as_slice()).collect();
            let (out, stats) = InaSwitch::default().aggregate(&views, WireInt::Int32);
            prop_assert!(stats.saturated_slots == 0, "saturated");
            for j in 0..d {
                let exact: i64 = msgs.iter().map(|m| m[j]).sum();
                prop_assert!(out[j] == exact, "slot {j}");
            }
            Ok(())
        });
    }
}
