//! `net` — a real transport layer + staged collectives, so IntSGD rounds
//! move actual bytes between ranks instead of folding borrowed slices in
//! one address space.
//!
//! The paper's headline systems claim is that IntSGD "can be tailored for
//! the popular all-reduce primitive" because every message is integers.
//! Until this module, the repository only *simulated* that property: the
//! collectives were leader-side folds over `&[&IntVec]`, and `netsim`
//! modeled wire time with alpha-beta costs. This subsystem closes the
//! loop:
//!
//! - [`Transport`] — point-to-point framed messages between ranks, with
//!   two implementations: [`ChannelTransport`] (in-process mailboxes,
//!   tier-1 testable, no syscalls) and [`TcpTransport`] (loopback
//!   `std::net` sockets, length-prefixed frames, no extra crates) — plus
//!   [`FaultTransport`], a deterministic seeded fault injector over any
//!   transport (drop / duplicate / corrupt / truncate / delay frames,
//!   kill a rank at a chosen round or op) so every failure mode is
//!   reproducible in tier-1.
//! - [`staged`] — ring all-reduce and recursive halving-doubling
//!   all-reduce for integer messages, plus ring all-gather for the codec
//!   byte streams. Integer addition is exactly associative, so every
//!   staged schedule is **bit-identical** to the leader-side rank-order
//!   fold (`collective::allreduce_intvec`) — `tests/net_parity.rs` pins
//!   this over real sockets for the whole compressor zoo.
//! - [`TransportReducer`] — plugs the staged collectives into the engine's
//!   reduce phase next to `SerialReducer` / the pool reducer, so a full
//!   training round (`Coordinator::train_over`, `repro net-bench`) runs
//!   its integer aggregation over the wire.
//!
//! **Failure model** (DESIGN.md §7). Every fallible operation returns a
//! typed [`NetError`] carrying the implicated rank and collective round id
//! — never a hang, never an untyped string the caller cannot classify.
//! Recoverable faults (timeouts, corrupt/replayed frames) fail the
//! *round*; the [`TransportReducer`] retries the collective from the
//! rank messages, which are untouched by the failed attempt, so a retried
//! round is bit-identical to an unfaulted one. A [`NetError::PeerDead`]
//! is permanent: it propagates to the `Coordinator`, which shrinks the
//! world to the survivors and re-runs the round at the smaller n.
//!
//! Frames are self-describing (`frame`: round id, per-pair sequence
//! number, lane width, element count, FNV-1a checksum over the payload)
//! and reuse the byte layouts of `compress::wire` for codec payloads —
//! the wire format here is the one the paper's byte counts are derived
//! from, so `netsim`'s modeled bytes and the measured socket time compare
//! like with like (`netsim::Network::round_breakdown_measured`).
//!
//! **Deadlock discipline.** Staged collectives make every rank send before
//! it receives within a step. `ChannelTransport` mailboxes are unbounded,
//! so sends never block. `TcpTransport` sockets are finite: its `send`
//! keeps draining inbound frames into per-peer inboxes whenever the kernel
//! applies backpressure, so a full mesh of mutually-sending ranks always
//! makes progress (see `tcp.rs`).

pub mod channel;
pub mod faults;
pub mod frame;
pub mod poll;
pub mod reducer;
pub mod staged;
pub mod tcp;

pub use channel::ChannelTransport;
pub use faults::{FaultPlan, FaultStats, FaultTransport, KillAt};
pub use frame::{FrameHeader, PayloadKind, HEADER_BYTES};
pub use poll::MuxTransport;
pub use reducer::{StagedAlgo, TransportReducer};
pub use tcp::TcpTransport;

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

/// Sentinel for "no rank attributed yet" in a [`NetError`] (stamped by the
/// layer that knows the peer).
pub const UNKNOWN_RANK: usize = usize::MAX;

/// Sentinel for "no collective round attributed yet" in a [`NetError`]
/// (transports don't know the round; the staged collectives stamp it).
pub const UNKNOWN_ROUND: u32 = u32::MAX;

/// Typed failure of a transport operation or staged collective. Every
/// variant names the implicated rank and the collective round id, so the
/// recovery layers can *classify* instead of parsing strings: everything
/// except [`NetError::PeerDead`] is recoverable by retrying the round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NetError {
    /// No frame arrived from `rank` before the deadline
    /// (`Transport::set_timeout`, default 30 s, env
    /// `INTSGD_NET_TIMEOUT_MS`).
    Timeout { rank: usize, round: u32 },
    /// A frame failed validation: framing underrun, bad length, unknown
    /// kind, checksum mismatch, or a payload that disagrees with its
    /// header.
    Corrupt { rank: usize, round: u32, detail: String },
    /// A duplicated, reordered, or otherwise out-of-sequence frame inside
    /// the current round — the per-peer round/seq guard rejected it.
    Replay { rank: usize, round: u32, detail: String },
    /// The peer is gone for good (connection closed, endpoint dropped, or
    /// killed by fault injection). Not recoverable by retry: the world
    /// must shrink to the survivors.
    PeerDead { rank: usize, round: u32 },
    /// This rank bailed out because a peer already failed the round (the
    /// cooperative abort flag, `Transport::set_abort`) — the interesting
    /// error is the peer's.
    Aborted { rank: usize, round: u32 },
}

impl NetError {
    /// The implicated rank ([`UNKNOWN_RANK`] when unattributed).
    pub fn rank(&self) -> usize {
        match self {
            NetError::Timeout { rank, .. }
            | NetError::Corrupt { rank, .. }
            | NetError::Replay { rank, .. }
            | NetError::PeerDead { rank, .. }
            | NetError::Aborted { rank, .. } => *rank,
        }
    }

    /// The collective round id ([`UNKNOWN_ROUND`] when unattributed).
    pub fn round(&self) -> u32 {
        match self {
            NetError::Timeout { round, .. }
            | NetError::Corrupt { round, .. }
            | NetError::Replay { round, .. }
            | NetError::PeerDead { round, .. }
            | NetError::Aborted { round, .. } => *round,
        }
    }

    fn round_mut(&mut self) -> &mut u32 {
        match self {
            NetError::Timeout { round, .. }
            | NetError::Corrupt { round, .. }
            | NetError::Replay { round, .. }
            | NetError::PeerDead { round, .. }
            | NetError::Aborted { round, .. } => round,
        }
    }

    fn rank_mut(&mut self) -> &mut usize {
        match self {
            NetError::Timeout { rank, .. }
            | NetError::Corrupt { rank, .. }
            | NetError::Replay { rank, .. }
            | NetError::PeerDead { rank, .. }
            | NetError::Aborted { rank, .. } => rank,
        }
    }

    /// Stamp the collective round id if it is still unknown.
    pub fn at_round(mut self, round: u32) -> NetError {
        if self.round() == UNKNOWN_ROUND {
            *self.round_mut() = round;
        }
        self
    }

    /// Stamp the implicated rank if it is still unknown.
    pub fn with_rank(mut self, rank: usize) -> NetError {
        if self.rank() == UNKNOWN_RANK {
            *self.rank_mut() = rank;
        }
        self
    }

    /// Rewrite the rank through `f` (the world re-keying adapter uses this
    /// to translate physical endpoint ranks back to survivor ranks).
    pub fn map_rank(mut self, f: impl FnOnce(usize) -> usize) -> NetError {
        let r = self.rank();
        if r != UNKNOWN_RANK {
            *self.rank_mut() = f(r);
        }
        self
    }

    /// Permanent failures shrink the world; everything else retries.
    pub fn is_peer_dead(&self) -> bool {
        matches!(self, NetError::PeerDead { .. })
    }

    /// Wrap a failed narrowing cast (`util::cast`) as a corrupt-frame
    /// error: an element count, lane tag, or seq that overflows its wire
    /// type can only come from hostile or damaged bytes, never from a
    /// well-formed peer.
    pub fn from_cast(e: crate::util::cast::CastError, rank: usize, round: u32) -> NetError {
        NetError::Corrupt { rank, round, detail: e.to_string() }
    }
}

fn fmt_rank(rank: usize) -> String {
    if rank == UNKNOWN_RANK {
        "?".into()
    } else {
        rank.to_string()
    }
}

fn fmt_round(round: u32) -> String {
    if round == UNKNOWN_ROUND {
        "?".into()
    } else {
        round.to_string()
    }
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Timeout { rank, round } => write!(
                f,
                "timed out waiting on rank {} in round {}",
                fmt_rank(*rank),
                fmt_round(*round)
            ),
            NetError::Corrupt { rank, round, detail } => write!(
                f,
                "corrupt frame from rank {} in round {}: {detail}",
                fmt_rank(*rank),
                fmt_round(*round)
            ),
            NetError::Replay { rank, round, detail } => write!(
                f,
                "replayed/out-of-order frame from rank {} in round {}: {detail}",
                fmt_rank(*rank),
                fmt_round(*round)
            ),
            NetError::PeerDead { rank, round } => write!(
                f,
                "rank {} is dead (connection closed) in round {}",
                fmt_rank(*rank),
                fmt_round(*round)
            ),
            NetError::Aborted { rank, round } => write!(
                f,
                "round {} aborted waiting on rank {} (a peer failed first)",
                fmt_round(*round),
                fmt_rank(*rank)
            ),
        }
    }
}

impl std::error::Error for NetError {}

/// Default blocking-IO deadline: env `INTSGD_NET_TIMEOUT_MS` or 30 s. A
/// dead or wedged peer must fail the collective with a typed
/// [`NetError::Timeout`], not hang the survivors; CI sets the env var so a
/// stalled rank burns milliseconds, not the full default.
pub fn default_io_timeout() -> Duration {
    std::env::var("INTSGD_NET_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or_else(|| Duration::from_secs(30))
}

/// Point-to-point message transport between the `world()` ranks of one
/// job. A message is one frame (`frame::encode_frame` bytes); transports
/// deliver frames whole, in order, per ordered (sender, receiver) pair.
///
/// Contract for implementations:
/// - `send` may apply backpressure but must keep consuming inbound frames
///   while blocked (the staged collectives' deadlock-freedom rests on it);
/// - `recv` blocks until the next frame *from that peer* arrives, leaving
///   frames from other peers queued;
/// - blocking operations give up after the configured timeout
///   ([`Transport::set_timeout`]) with [`NetError::Timeout`], and bail
///   early with [`NetError::Aborted`] once the installed abort flag
///   ([`Transport::set_abort`]) is raised — a failed peer must not cost
///   the survivors a full timeout;
/// - sending to or receiving from `self.rank()` is a caller bug
///   (collectives never schedule self-messages) and may panic.
pub trait Transport: Send {
    /// This endpoint's rank in `[0, world)`.
    fn rank(&self) -> usize;

    /// Number of ranks in the job.
    fn world(&self) -> usize;

    /// Ship one framed message to `to`.
    fn send(&mut self, to: usize, frame: &[u8]) -> Result<(), NetError>;

    /// Receive the next framed message from `from` into `out`. The
    /// previous contents of `out` are discarded; implementations may
    /// replace the buffer outright (handing over the arrival buffer)
    /// rather than copying into it.
    fn recv(&mut self, from: usize, out: &mut Vec<u8>) -> Result<(), NetError>;

    /// Bound blocking sends/receives (default: implementation-defined,
    /// see [`default_io_timeout`]). The deadline applies **per logical
    /// operation**: one `send` or `recv` call as a whole must fail with
    /// [`NetError::Timeout`] once the duration elapses, even if every
    /// individual syscall keeps making partial progress — a peer that
    /// accepts one byte per pump iteration is still a timeout, not a
    /// live connection. Implementations without blocking ops may ignore
    /// it.
    fn set_timeout(&mut self, _timeout: Duration) {}

    /// Install a cooperative abort flag: blocking operations poll it and
    /// return [`NetError::Aborted`] once raised, so one rank's failure
    /// ends the whole round in milliseconds instead of a timeout.
    fn set_abort(&mut self, _flag: Arc<AtomicBool>) {}
}

#[cfg(test)]
mod tests {
    use super::frame::{encode_frame, expect_frame, FrameHeader, PayloadKind};
    use super::*;

    /// Shared transport conformance check: ordering per pair, peer
    /// isolation, and frame integrity end to end. Drives a full mesh from
    /// n scoped threads, one per endpoint.
    pub(crate) fn exercise_mesh<T: Transport>(mut endpoints: Vec<T>) {
        let n = endpoints.len();
        std::thread::scope(|s| {
            for (rank, ep) in endpoints.iter_mut().enumerate() {
                s.spawn(move || {
                    let mut buf = Vec::new();
                    let mut rx = Vec::new();
                    // every ordered pair exchanges two messages; payload
                    // encodes (sender, receiver, sequence) so misrouting
                    // or reordering is visible
                    for seq in 0..2u32 {
                        for peer in 0..n {
                            if peer == rank {
                                continue;
                            }
                            let payload =
                                vec![rank as u8, peer as u8, seq as u8, 0xAB];
                            encode_frame(
                                FrameHeader {
                                    round: seq,
                                    seq,
                                    kind: PayloadKind::Bytes,
                                    elems: 4,
                                },
                                &payload,
                                &mut buf,
                            );
                            ep.send(peer, &buf).expect("send");
                        }
                        for peer in 0..n {
                            if peer == rank {
                                continue;
                            }
                            ep.recv(peer, &mut rx).expect("recv");
                            let body = expect_frame(&rx, seq, PayloadKind::Bytes, 4)
                                .expect("frame");
                            assert_eq!(
                                body,
                                &[peer as u8, rank as u8, seq as u8, 0xAB],
                                "rank {rank} <- peer {peer} seq {seq}"
                            );
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn net_error_accessors_and_stamping() {
        let e = NetError::Timeout { rank: UNKNOWN_RANK, round: UNKNOWN_ROUND };
        let e = e.with_rank(3).at_round(7);
        assert_eq!(e.rank(), 3);
        assert_eq!(e.round(), 7);
        // stamping never overwrites a known field
        let e = e.with_rank(9).at_round(9);
        assert_eq!((e.rank(), e.round()), (3, 7));
        assert!(!e.is_peer_dead());
        assert!(NetError::PeerDead { rank: 0, round: 0 }.is_peer_dead());
        // rank remapping rewrites known ranks only
        let e = e.map_rank(|r| r + 10);
        assert_eq!(e.rank(), 13);
        let u = NetError::Timeout { rank: UNKNOWN_RANK, round: 0 }.map_rank(|r| r + 10);
        assert_eq!(u.rank(), UNKNOWN_RANK);
    }

    #[test]
    fn net_error_displays_classifiably() {
        let dead = NetError::PeerDead { rank: 2, round: 5 }.to_string();
        assert!(dead.contains("closed") && dead.contains('2'), "{dead}");
        let t = NetError::Timeout { rank: 1, round: UNKNOWN_ROUND }.to_string();
        assert!(t.contains("timed out") && t.contains('?'), "{t}");
    }
}
