//! `net` — a real transport layer + staged collectives, so IntSGD rounds
//! move actual bytes between ranks instead of folding borrowed slices in
//! one address space.
//!
//! The paper's headline systems claim is that IntSGD "can be tailored for
//! the popular all-reduce primitive" because every message is integers.
//! Until this module, the repository only *simulated* that property: the
//! collectives were leader-side folds over `&[&IntVec]`, and `netsim`
//! modeled wire time with alpha-beta costs. This subsystem closes the
//! loop:
//!
//! - [`Transport`] — point-to-point framed messages between ranks, with
//!   two implementations: [`ChannelTransport`] (in-process mailboxes,
//!   tier-1 testable, no syscalls) and [`TcpTransport`] (loopback
//!   `std::net` sockets, length-prefixed frames, no extra crates).
//! - [`staged`] — ring all-reduce and recursive halving-doubling
//!   all-reduce for integer messages, plus ring all-gather for the codec
//!   byte streams. Integer addition is exactly associative, so every
//!   staged schedule is **bit-identical** to the leader-side rank-order
//!   fold (`collective::allreduce_intvec`) — `tests/net_parity.rs` pins
//!   this over real sockets for the whole compressor zoo.
//! - [`TransportReducer`] — plugs the staged collectives into the engine's
//!   reduce phase next to `SerialReducer` / the pool reducer, so a full
//!   training round (`Coordinator::train_over`, `repro net-bench`) runs
//!   its integer aggregation over the wire.
//!
//! Frames are self-describing (`frame`: round id, lane width, element
//! count, FNV-1a checksum over the payload) and reuse the byte layouts of
//! `compress::wire` for codec payloads — the wire format here is the one
//! the paper's byte counts are derived from, so `netsim`'s modeled bytes
//! and the measured socket time compare like with like
//! (`netsim::Network::round_breakdown_measured`).
//!
//! **Deadlock discipline.** Staged collectives make every rank send before
//! it receives within a step. `ChannelTransport` mailboxes are unbounded,
//! so sends never block. `TcpTransport` sockets are finite: its `send`
//! keeps draining inbound frames into per-peer inboxes whenever the kernel
//! applies backpressure, so a full mesh of mutually-sending ranks always
//! makes progress (see `tcp.rs`).

pub mod channel;
pub mod frame;
pub mod reducer;
pub mod staged;
pub mod tcp;

pub use channel::ChannelTransport;
pub use frame::{FrameHeader, PayloadKind, HEADER_BYTES};
pub use reducer::{StagedAlgo, TransportReducer};
pub use tcp::TcpTransport;

use anyhow::Result;

/// Point-to-point message transport between the `world()` ranks of one
/// job. A message is one frame (`frame::encode_frame` bytes); transports
/// deliver frames whole, in order, per ordered (sender, receiver) pair.
///
/// Contract for implementations:
/// - `send` may apply backpressure but must keep consuming inbound frames
///   while blocked (the staged collectives' deadlock-freedom rests on it);
/// - `recv` blocks until the next frame *from that peer* arrives, leaving
///   frames from other peers queued;
/// - sending to or receiving from `self.rank()` is a caller bug
///   (collectives never schedule self-messages) and may panic.
pub trait Transport: Send {
    /// This endpoint's rank in `[0, world)`.
    fn rank(&self) -> usize;

    /// Number of ranks in the job.
    fn world(&self) -> usize;

    /// Ship one framed message to `to`.
    fn send(&mut self, to: usize, frame: &[u8]) -> Result<()>;

    /// Receive the next framed message from `from` into `out`. The
    /// previous contents of `out` are discarded; implementations may
    /// replace the buffer outright (handing over the arrival buffer)
    /// rather than copying into it.
    fn recv(&mut self, from: usize, out: &mut Vec<u8>) -> Result<()>;
}

#[cfg(test)]
mod tests {
    use super::frame::{encode_frame, expect_frame, FrameHeader, PayloadKind};
    use super::*;

    /// Shared transport conformance check: ordering per pair, peer
    /// isolation, and frame integrity end to end. Drives a full mesh from
    /// n scoped threads, one per endpoint.
    pub(crate) fn exercise_mesh<T: Transport>(mut endpoints: Vec<T>) {
        let n = endpoints.len();
        std::thread::scope(|s| {
            for (rank, ep) in endpoints.iter_mut().enumerate() {
                s.spawn(move || {
                    let mut buf = Vec::new();
                    let mut rx = Vec::new();
                    // every ordered pair exchanges two messages; payload
                    // encodes (sender, receiver, sequence) so misrouting
                    // or reordering is visible
                    for seq in 0..2u32 {
                        for peer in 0..n {
                            if peer == rank {
                                continue;
                            }
                            let payload =
                                vec![rank as u8, peer as u8, seq as u8, 0xAB];
                            encode_frame(
                                FrameHeader {
                                    round: seq,
                                    kind: PayloadKind::Bytes,
                                    elems: 4,
                                },
                                &payload,
                                &mut buf,
                            );
                            ep.send(peer, &buf).expect("send");
                        }
                        for peer in 0..n {
                            if peer == rank {
                                continue;
                            }
                            ep.recv(peer, &mut rx).expect("recv");
                            let body = expect_frame(&rx, seq, PayloadKind::Bytes, 4)
                                .expect("frame");
                            assert_eq!(
                                body,
                                &[peer as u8, rank as u8, seq as u8, 0xAB],
                                "rank {rank} <- peer {peer} seq {seq}"
                            );
                        }
                    }
                });
            }
        });
    }
}
