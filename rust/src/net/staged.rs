//! Staged collectives: the real multi-step schedules of ring all-reduce,
//! recursive halving-doubling all-reduce, and ring all-gather, executed
//! over any [`Transport`].
//!
//! **Bit-parity argument.** Every integer collective here computes, per
//! coordinate, a sum of the same n rank values the leader-side fold
//! (`collective::allreduce_intvec`) computes — just associated in the
//! schedule's order instead of rank order. The accumulator is `i64` end
//! to end, the summands are wire-bounded (|aggregate| fits the caller's
//! `wire` lane, so no i64 overflow is reachable), and i64 addition is
//! exactly associative and commutative — therefore any schedule produces
//! the identical bit pattern. `tests/net_parity.rs` pins this over real
//! TCP sockets for the whole compressor zoo.
//!
//! **Failure model.** Every send/recv failure surfaces as a typed
//! [`NetError`] stamped with the peer rank and the collective's round id.
//! Each frame additionally carries a per-(sender, receiver) **sequence
//! number** — the hop index of the schedule — checked on receive
//! ([`frame::check_frame`]): a duplicated or reordered frame is a
//! [`NetError::Replay`], a *gap* (the awaited frame was dropped and a
//! later one arrived) fails immediately instead of burning the timeout,
//! and a frame from an **older round id** is silently discarded — that is
//! what makes round retry sound: the `TransportReducer` reruns a failed
//! collective under a fresh round id, and the aborted attempt's leftovers
//! are skipped, not misread ([`StagedScratch::take_skipped`] counts them).
//!
//! **Wire width of partial sums.** The caller passes the lane every
//! *partial* sum provably fits. For IntSGD this is the aggregate wire
//! type itself: each rank clips to `floor((2^{b-1}-1)/n)`, so any subset
//! of ranks sums within the full-aggregate bound (the paper's wire-fit
//! proof, `IntSgd::local_clip`). `pack_partials` range-checks every
//! element, so a violated proof is a loud decode error, never silent
//! corruption. [`partial_sum_lanes`] derives a safe width from the
//! messages themselves when no proof is at hand.
//!
//! Scratch buffers are taken from a per-call [`StagedScratch`] so a
//! steady-state caller (the [`super::TransportReducer`]) reuses payload /
//! frame / receive buffers across rounds.

use crate::compress::intvec::{IntVec, Lanes};
use crate::util::cast;

use super::frame::{
    add_partials, block_seq, check_frame, classify_round, copy_partials, decode_frame,
    encode_frame, pack_partials, FrameCheck, FrameHeader, PayloadKind, HEADER_BYTES,
};
use super::{NetError, Transport};

/// Reused buffers for one endpoint's staged collectives.
#[derive(Default)]
pub struct StagedScratch {
    payload: Vec<u8>,
    frame: Vec<u8>,
    rx: Vec<u8>,
    starts: Vec<usize>,
    /// Halving-doubling step log: (partner, keep_lo, keep_hi, give_lo,
    /// give_hi), replayed in reverse for the all-gather phase.
    steps: Vec<(usize, usize, usize, usize, usize)>,
    /// Stale frames (older round ids, leftovers of aborted attempts)
    /// discarded by the round/seq guard since the last `take_skipped`.
    skipped: u64,
    /// Pipeline block index folded into every frame seq
    /// ([`super::frame::block_seq`]). Zero for barrier-path collectives;
    /// the streamed driver stamps the gradient-block index here so frames
    /// of adjacent in-flight blocks can never satisfy each other's guard.
    block: u32,
}

impl StagedScratch {
    /// Read and reset the stale-frame counter (retry accounting).
    pub fn take_skipped(&mut self) -> u64 {
        std::mem::take(&mut self.skipped)
    }

    /// Stamp the pipeline block index into subsequent collectives' frame
    /// seqs. Every rank of one collective must agree on it.
    pub fn set_block(&mut self, block: u32) {
        self.block = block;
    }
}

/// What one receive awaits: the `(round, seq)` guard plus the shape.
#[derive(Clone, Copy)]
struct Want {
    round: u32,
    seq: u32,
    kind: PayloadKind,
    elems: usize,
}

/// Receive the frame `want` describes from `from`, skipping stale frames
/// (older round ids) and rejecting everything else with a typed error.
/// On `Ok`, the payload is `&scratch.rx[HEADER_BYTES..]`.
fn recv_expect(
    t: &mut dyn Transport,
    from: usize,
    want: Want,
    scratch: &mut StagedScratch,
) -> Result<(), NetError> {
    loop {
        t.recv(from, &mut scratch.rx).map_err(|e| e.at_round(want.round))?;
        match check_frame(&scratch.rx, want.round, want.seq, want.kind, want.elems) {
            Ok(FrameCheck::Fresh) => return Ok(()),
            Ok(FrameCheck::Stale) => {
                scratch.skipped += 1;
                continue;
            }
            Err(e) => return Err(e.with_rank(from).at_round(want.round)),
        }
    }
}

/// Stamp a local (frame/pack) error with this endpoint's context.
fn local(e: NetError, rank: usize, round: u32) -> NetError {
    e.with_rank(rank).at_round(round)
}

/// Narrowest lane provably holding every partial sum of `msgs` — the sum
/// of per-rank magnitudes bounds any subset's sum. Callers with a
/// stronger proof (IntSGD's clip) pass their wire lane directly.
pub fn partial_sum_lanes<'a, I>(msgs: I) -> Lanes
where
    I: IntoIterator<Item = &'a IntVec>,
{
    let bound: i64 = msgs
        .into_iter()
        .map(|m| m.max_abs())
        .fold(0i64, |acc, x| acc.saturating_add(x));
    Lanes::for_bound(bound)
}

/// Ring all-reduce of one integer message: reduce-scatter over n-1 steps
/// on n chunks, then ring all-gather of the finished chunks. On return
/// `out` holds the exact integer sum over all ranks — bit-identical to
/// `collective::allreduce_intvec` (module docs) — and every rank holds
/// the same vector.
pub fn ring_allreduce_ints(
    t: &mut dyn Transport,
    msg: &IntVec,
    wire: Lanes,
    round: u32,
    scratch: &mut StagedScratch,
    out: &mut Vec<i64>,
) -> Result<(), NetError> {
    out.clear();
    out.resize(msg.len(), 0);
    msg.add_range_to(0, out);
    ring_allreduce_partials(t, wire, round, scratch, out)
}

/// The ring schedule over an already-widened local contribution: on entry
/// `out` holds this rank's summand, on return the exact aggregate. The
/// two-level collective's inter-leader stage reuses this with partial
/// group sums as the contributions.
fn ring_allreduce_partials(
    t: &mut dyn Transport,
    wire: Lanes,
    round: u32,
    scratch: &mut StagedScratch,
    out: &mut Vec<i64>,
) -> Result<(), NetError> {
    let n = t.world();
    let r = t.rank();
    let d = out.len();
    if n == 1 {
        return Ok(());
    }
    let kind = PayloadKind::of_lanes(wire);
    let block = scratch.block;
    let cfail = |e: cast::CastError| NetError::from_cast(e, r, round);
    let right = (r + 1) % n;
    let left = (r + n - 1) % n;
    // chunk c covers starts[c]..starts[c + 1]
    scratch.starts.clear();
    scratch.starts.extend((0..=n).map(|c| c * d / n));

    // reduce-scatter: at step s, send accumulated chunk (r - s) right,
    // fold received chunk (r - 1 - s) from the left; the hop index s is
    // the frame's sequence number on the (r -> right) pair
    for s in 0..n - 1 {
        let send_c = (r + n - s) % n;
        let recv_c = (r + 2 * n - 1 - s) % n;
        let (slo, shi) = (scratch.starts[send_c], scratch.starts[send_c + 1]);
        let seq = block_seq(block, cast::to_u32(s).map_err(cfail)?);
        pack_partials(&out[slo..shi], wire, &mut scratch.payload)
            .map_err(|e| local(e, r, round))?;
        encode_frame(
            FrameHeader { round, seq, kind, elems: cast::to_u32(shi - slo).map_err(cfail)? },
            &scratch.payload,
            &mut scratch.frame,
        );
        t.send(right, &scratch.frame).map_err(|e| e.at_round(round))?;
        let (rlo, rhi) = (scratch.starts[recv_c], scratch.starts[recv_c + 1]);
        recv_expect(t, left, Want { round, seq, kind, elems: rhi - rlo }, scratch)?;
        add_partials(&scratch.rx[HEADER_BYTES..], wire, &mut out[rlo..rhi])
            .map_err(|e| local(e, left, round))?;
    }
    // all-gather: rank r owns the finished chunk (r + 1); circulate the
    // finished chunks around the ring (seq continues where phase 1 ended)
    for s in 0..n - 1 {
        let seq = block_seq(block, cast::to_u32(n - 1 + s).map_err(cfail)?);
        let send_c = (r + 1 + n - s) % n;
        let recv_c = (r + n - s) % n;
        let (slo, shi) = (scratch.starts[send_c], scratch.starts[send_c + 1]);
        pack_partials(&out[slo..shi], wire, &mut scratch.payload)
            .map_err(|e| local(e, r, round))?;
        encode_frame(
            FrameHeader { round, seq, kind, elems: cast::to_u32(shi - slo).map_err(cfail)? },
            &scratch.payload,
            &mut scratch.frame,
        );
        t.send(right, &scratch.frame).map_err(|e| e.at_round(round))?;
        let (rlo, rhi) = (scratch.starts[recv_c], scratch.starts[recv_c + 1]);
        recv_expect(t, left, Want { round, seq, kind, elems: rhi - rlo }, scratch)?;
        copy_partials(&scratch.rx[HEADER_BYTES..], wire, &mut out[rlo..rhi])
            .map_err(|e| local(e, left, round))?;
    }
    Ok(())
}

/// Recursive halving-doubling all-reduce (Rabenseifner): reduce-scatter
/// by vector halving with doubling distances, then all-gather by vector
/// doubling — log2(n) rounds of half-sized exchanges instead of the
/// ring's n-1 chunk hops, the latency-optimal schedule for small
/// messages. Requires a power-of-two world; other sizes fall back to the
/// ring schedule (same bits either way — module docs).
pub fn halving_allreduce_ints(
    t: &mut dyn Transport,
    msg: &IntVec,
    wire: Lanes,
    round: u32,
    scratch: &mut StagedScratch,
    out: &mut Vec<i64>,
) -> Result<(), NetError> {
    out.clear();
    out.resize(msg.len(), 0);
    msg.add_range_to(0, out);
    halving_allreduce_partials(t, wire, round, scratch, out)
}

/// Halving-doubling over an already-widened local contribution (see
/// [`ring_allreduce_partials`]); non-power-of-two worlds fall back to the
/// ring schedule.
fn halving_allreduce_partials(
    t: &mut dyn Transport,
    wire: Lanes,
    round: u32,
    scratch: &mut StagedScratch,
    out: &mut Vec<i64>,
) -> Result<(), NetError> {
    let n = t.world();
    if !n.is_power_of_two() {
        return ring_allreduce_partials(t, wire, round, scratch, out);
    }
    let r = t.rank();
    let d = out.len();
    if n == 1 {
        return Ok(());
    }
    let kind = PayloadKind::of_lanes(wire);
    let block = scratch.block;
    let cfail = |e: cast::CastError| NetError::from_cast(e, r, round);

    // reduce-scatter: each step, partner pairs split their common segment;
    // each sends the half it gives up and folds the half it keeps. Both
    // sides run the same step index, which doubles as the frame seq.
    scratch.steps.clear();
    let (mut lo, mut hi) = (0usize, d);
    let mut dist = n / 2;
    let mut seq = 0u32;
    while dist >= 1 {
        let partner = r ^ dist;
        let mid = lo + (hi - lo) / 2;
        let (keep, give) = if r & dist == 0 {
            ((lo, mid), (mid, hi))
        } else {
            ((mid, hi), (lo, mid))
        };
        pack_partials(&out[give.0..give.1], wire, &mut scratch.payload)
            .map_err(|e| local(e, r, round))?;
        encode_frame(
            FrameHeader {
                round,
                seq: block_seq(block, seq),
                kind,
                elems: cast::to_u32(give.1 - give.0).map_err(cfail)?,
            },
            &scratch.payload,
            &mut scratch.frame,
        );
        t.send(partner, &scratch.frame).map_err(|e| e.at_round(round))?;
        recv_expect(
            t,
            partner,
            Want { round, seq: block_seq(block, seq), kind, elems: keep.1 - keep.0 },
            scratch,
        )?;
        add_partials(&scratch.rx[HEADER_BYTES..], wire, &mut out[keep.0..keep.1])
            .map_err(|e| local(e, partner, round))?;
        scratch.steps.push((partner, keep.0, keep.1, give.0, give.1));
        lo = keep.0;
        hi = keep.1;
        dist /= 2;
        seq += 1;
    }
    // all-gather: replay in reverse; I own my keep segment fully summed,
    // the partner owns the give segment — exchange to own their union.
    // Both partners replay the identical order, so seq keeps counting up.
    for step in (0..scratch.steps.len()).rev() {
        let (partner, klo, khi, glo, ghi) = scratch.steps[step];
        pack_partials(&out[klo..khi], wire, &mut scratch.payload)
            .map_err(|e| local(e, r, round))?;
        encode_frame(
            FrameHeader {
                round,
                seq: block_seq(block, seq),
                kind,
                elems: cast::to_u32(khi - klo).map_err(cfail)?,
            },
            &scratch.payload,
            &mut scratch.frame,
        );
        t.send(partner, &scratch.frame).map_err(|e| e.at_round(round))?;
        recv_expect(
            t,
            partner,
            Want { round, seq: block_seq(block, seq), kind, elems: ghi - glo },
            scratch,
        )?;
        copy_partials(&scratch.rx[HEADER_BYTES..], wire, &mut out[glo..ghi])
            .map_err(|e| local(e, partner, round))?;
        seq += 1;
    }
    Ok(())
}

/// Leader-subworld view for the two-level schedule: virtual rank v is
/// physical rank `v * group`. Inner-transport errors carry physical
/// ranks; they are translated into leader space so the staged guard logic
/// stays in one rank space, and [`two_level_allreduce_ints`] maps every
/// leader-stage error back to physical ranks before surfacing it.
struct LeaderView<'a> {
    inner: &'a mut dyn Transport,
    group: usize,
    world: usize,
    vrank: usize,
}

impl LeaderView<'_> {
    fn to_leader_space(&self, e: NetError) -> NetError {
        let (group, world) = (self.group, self.world);
        e.map_rank(|phys| {
            if phys % group == 0 && phys / group < world {
                phys / group
            } else {
                // an error about a non-leader rank must not alias a
                // leader once mapped back out — surface it unattributed
                super::UNKNOWN_RANK
            }
        })
    }
}

impl Transport for LeaderView<'_> {
    fn rank(&self) -> usize {
        self.vrank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&mut self, to: usize, frame: &[u8]) -> Result<(), NetError> {
        let phys = to * self.group;
        self.inner.send(phys, frame).map_err(|e| self.to_leader_space(e))
    }

    fn recv(&mut self, from: usize, out: &mut Vec<u8>) -> Result<(), NetError> {
        let phys = from * self.group;
        self.inner.recv(phys, out).map_err(|e| self.to_leader_space(e))
    }
}

/// Two-level hierarchical all-reduce: group members stream their whole
/// message to their group leader (rank `r - r % group`), the leader folds
/// them in **ascending rank order** onto its own message, the n/group
/// leaders run recursive halving-doubling over a [`LeaderView`] (ring
/// fallback when the leader count is not a power of two), and finally
/// each leader broadcasts the finished aggregate back down its group.
///
/// This trades the flat ring's (n-1)-hop latency wall for
/// `(group-1) + log2(n/group) + 1` hop generations — the schedule that
/// keeps scaling at n ∈ {64, 128} where every flat schedule stalls on
/// per-hop latency. Bit-parity with the leader-side fold holds by the
/// module-level associativity argument, and every *partial group sum*
/// still fits the caller's `wire` lane by IntSGD's clip proof: each rank
/// clips to `floor((2^{b-1}-1)/n)`, so any subset of ranks — a group, a
/// union of groups mid-halving — sums within the full-aggregate bound
/// (`pack_partials` still range-checks every element).
///
/// Degenerate groupings (`group <= 1`, `group > n`, or `group` not
/// dividing n) fall back to the flat ring — same bits either way.
pub fn two_level_allreduce_ints(
    t: &mut dyn Transport,
    msg: &IntVec,
    wire: Lanes,
    round: u32,
    group: usize,
    scratch: &mut StagedScratch,
    out: &mut Vec<i64>,
) -> Result<(), NetError> {
    let n = t.world();
    if group <= 1 || group > n || n % group != 0 {
        return ring_allreduce_ints(t, msg, wire, round, scratch, out);
    }
    let r = t.rank();
    let d = msg.len();
    out.clear();
    out.resize(d, 0);
    msg.add_range_to(0, out);
    let kind = PayloadKind::of_lanes(wire);
    let block = scratch.block;
    let d32 = cast::to_u32(d).map_err(|e| NetError::from_cast(e, r, round))?;
    let leader = r - r % group;
    if r != leader {
        // member: ship the whole message up, await the finished aggregate.
        // Up-hop and down-hop run on distinct ordered pairs, so both are
        // hop 0 of their pair.
        pack_partials(out, wire, &mut scratch.payload).map_err(|e| local(e, r, round))?;
        encode_frame(
            FrameHeader { round, seq: block_seq(block, 0), kind, elems: d32 },
            &scratch.payload,
            &mut scratch.frame,
        );
        t.send(leader, &scratch.frame).map_err(|e| e.at_round(round))?;
        recv_expect(
            t,
            leader,
            Want { round, seq: block_seq(block, 0), kind, elems: d },
            scratch,
        )?;
        copy_partials(&scratch.rx[HEADER_BYTES..], wire, out)
            .map_err(|e| local(e, leader, round))?;
        return Ok(());
    }
    // leader: fold the group's messages in ascending rank order — the
    // pinned fold order (any order gives the same bits; pinning it keeps
    // the schedule deterministic and the docs honest)
    for m in r + 1..r + group {
        recv_expect(
            t,
            m,
            Want { round, seq: block_seq(block, 0), kind, elems: d },
            scratch,
        )?;
        add_partials(&scratch.rx[HEADER_BYTES..], wire, out)
            .map_err(|e| local(e, m, round))?;
    }
    // inter-node stage: halving-doubling across the leaders, partial
    // group sums as contributions (they fit `wire` — doc comment above)
    {
        let mut leaders =
            LeaderView { inner: t, group, world: n / group, vrank: r / group };
        halving_allreduce_partials(&mut leaders, wire, round, scratch, out)
            .map_err(|e| e.map_rank(|v| v * group))?;
    }
    // broadcast-down: the finished aggregate, one frame per member
    pack_partials(out, wire, &mut scratch.payload).map_err(|e| local(e, r, round))?;
    encode_frame(
        FrameHeader { round, seq: block_seq(block, 0), kind, elems: d32 },
        &scratch.payload,
        &mut scratch.frame,
    );
    for m in r + 1..r + group {
        t.send(m, &scratch.frame).map_err(|e| e.at_round(round))?;
    }
    Ok(())
}

/// Ring all-gather of opaque codec payloads (sparse / sign / QSGD /
/// NatSGD byte streams from `compress::wire`): after n-1 steps every rank
/// holds every rank's bytes. `out[i]` receives rank i's payload into a
/// reused buffer; payload sizes may differ per rank (the header carries
/// each frame's own length), so the guard checks `(round, seq, kind)` and
/// takes the length from the validated header.
pub fn ring_allgather_bytes(
    t: &mut dyn Transport,
    mine: &[u8],
    round: u32,
    scratch: &mut StagedScratch,
    out: &mut Vec<Vec<u8>>,
) -> Result<(), NetError> {
    let n = t.world();
    let r = t.rank();
    let cfail = |e: cast::CastError| NetError::from_cast(e, r, round);
    // intlint: allow(R2, reason="grows out to world size on first call; steady state reuses the per-rank buffers")
    out.resize_with(n, Vec::new);
    out[r].clear();
    out[r].extend_from_slice(mine);
    if n == 1 {
        return Ok(());
    }
    let right = (r + 1) % n;
    let left = (r + n - 1) % n;
    let block = scratch.block;
    for s in 0..n - 1 {
        let s32 = cast::to_u32(s).map_err(cfail)?;
        let send_origin = (r + n - s) % n;
        let recv_origin = (r + 2 * n - 1 - s) % n;
        let payload = &out[send_origin];
        // an over-long payload fails the checked cast (a frame's length
        // field is u32) instead of silently truncating on the wire
        encode_frame(
            FrameHeader {
                round,
                seq: block_seq(block, s32),
                kind: PayloadKind::Bytes,
                elems: cast::to_u32(payload.len()).map_err(cfail)?,
            },
            payload,
            &mut scratch.frame,
        );
        t.send(right, &scratch.frame).map_err(|e| e.at_round(round))?;
        // lengths differ per origin, so validate the header first and
        // take the payload length from it — round/stale classification is
        // the same shared guard `check_frame` uses
        let body_len = loop {
            t.recv(left, &mut scratch.rx).map_err(|e| e.at_round(round))?;
            let (h, body) =
                decode_frame(&scratch.rx).map_err(|e| local(e, left, round))?;
            match classify_round(h.round, round).map_err(|e| local(e, left, round))? {
                FrameCheck::Stale => {
                    scratch.skipped += 1;
                    continue;
                }
                FrameCheck::Fresh => {}
            }
            if h.seq != block_seq(block, s32) {
                return Err(NetError::Replay {
                    rank: left,
                    round,
                    detail: format!(
                        "unexpected frame (seq {}, expected {s}) at all-gather step {s}",
                        h.seq
                    ),
                });
            }
            if h.kind != PayloadKind::Bytes {
                return Err(NetError::Corrupt {
                    rank: left,
                    round,
                    detail: format!("expected Bytes payload, got {:?}", h.kind),
                });
            }
            break body.len();
        };
        let dst = &mut out[recv_origin];
        dst.clear();
        dst.extend_from_slice(&scratch.rx[scratch.rx.len() - body_len..]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::ChannelTransport;
    use super::*;
    use crate::collective::allreduce_intvec;
    use crate::util::Rng;

    type Staged = fn(
        &mut dyn Transport,
        &IntVec,
        Lanes,
        u32,
        &mut StagedScratch,
        &mut Vec<i64>,
    ) -> Result<(), NetError>;

    /// Run one staged all-reduce across n threads and require every
    /// rank's result to be bit-identical to the leader-side fold.
    fn assert_staged_matches_fold(algo: Staged, n: usize, d: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let msgs: Vec<IntVec> = (0..n)
            .map(|_| {
                let vals: Vec<i64> =
                    (0..d).map(|_| rng.below(255) as i64 - 127).collect();
                IntVec::from_i64(&vals, Lanes::I32)
            })
            .collect();
        let views: Vec<&IntVec> = msgs.iter().collect();
        let mut want = Vec::new();
        allreduce_intvec(&views, &mut want);
        let wire = partial_sum_lanes(msgs.iter());

        let mut endpoints = ChannelTransport::mesh(n);
        let results: Vec<Vec<i64>> = std::thread::scope(|s| {
            let handles: Vec<_> = endpoints
                .iter_mut()
                .zip(&msgs)
                .map(|(ep, msg)| {
                    s.spawn(move || {
                        let mut scratch = StagedScratch::default();
                        let mut out = Vec::new();
                        // two rounds over the same endpoints: scratch and
                        // sequencing must survive reuse
                        for round in 0..2 {
                            algo(ep, msg, wire, round, &mut scratch, &mut out)
                                .expect("staged all-reduce");
                        }
                        assert_eq!(scratch.take_skipped(), 0, "no stale frames");
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, got) in results.iter().enumerate() {
            assert_eq!(got, &want, "rank {rank} (n={n}, d={d})");
        }
    }

    #[test]
    fn ring_matches_leader_fold() {
        for (n, d) in [(1usize, 40usize), (2, 64), (3, 65), (4, 7), (5, 1000), (8, 0)] {
            assert_staged_matches_fold(ring_allreduce_ints, n, d, 11 + n as u64);
        }
    }

    #[test]
    fn halving_matches_leader_fold() {
        // power-of-two worlds take the halving schedule; 3 and 5 exercise
        // the documented ring fallback
        for (n, d) in [(1usize, 16usize), (2, 33), (4, 100), (8, 257), (3, 50), (5, 64)] {
            assert_staged_matches_fold(halving_allreduce_ints, n, d, 77 + n as u64);
        }
    }

    #[test]
    fn two_level_matches_leader_fold() {
        // fn items (not closures) so the shared harness's `Staged` alias
        // still fits; each pins one group size
        fn g2(
            t: &mut dyn Transport,
            m: &IntVec,
            w: Lanes,
            r: u32,
            s: &mut StagedScratch,
            o: &mut Vec<i64>,
        ) -> Result<(), NetError> {
            two_level_allreduce_ints(t, m, w, r, 2, s, o)
        }
        fn g4(
            t: &mut dyn Transport,
            m: &IntVec,
            w: Lanes,
            r: u32,
            s: &mut StagedScratch,
            o: &mut Vec<i64>,
        ) -> Result<(), NetError> {
            two_level_allreduce_ints(t, m, w, r, 4, s, o)
        }
        fn g3(
            t: &mut dyn Transport,
            m: &IntVec,
            w: Lanes,
            r: u32,
            s: &mut StagedScratch,
            o: &mut Vec<i64>,
        ) -> Result<(), NetError> {
            two_level_allreduce_ints(t, m, w, r, 3, s, o)
        }
        // power-of-two leader counts take halving; n=12/g=2 exercises the
        // six-leader ring fallback inside the leader stage; n=2/g=2 is a
        // single group (fold + broadcast, no inter-leader exchange)
        for (n, d) in [(4usize, 100usize), (8, 257), (2, 16), (12, 40)] {
            assert_staged_matches_fold(g2, n, d, 131 + n as u64);
        }
        for (n, d) in [(8usize, 129usize), (4, 64), (16, 1000)] {
            assert_staged_matches_fold(g4, n, d, 151 + n as u64);
        }
        // degenerate groupings (g > n, g does not divide n) fall back to
        // the ring; n=3/g=3 is a legitimate single group
        for (n, d) in [(4usize, 50usize), (3, 64), (1, 8)] {
            assert_staged_matches_fold(g3, n, d, 171 + n as u64);
        }
    }

    #[test]
    fn two_level_i8_wire_carries_clipped_group_partials() {
        // IntSGD's clip proof extends to the hierarchy: per-rank
        // |v| <= floor(127 / n) keeps every *group* partial sum (and
        // every union of groups mid-halving) inside i8
        let n = 8;
        let group = 4;
        let d = 333;
        let clip = 127 / n as i64;
        let mut rng = Rng::new(29);
        let msgs: Vec<IntVec> = (0..n)
            .map(|_| {
                let vals: Vec<i64> = (0..d)
                    .map(|_| rng.below(2 * clip as u64 + 1) as i64 - clip)
                    .collect();
                IntVec::from_i64(&vals, Lanes::I8)
            })
            .collect();
        let views: Vec<&IntVec> = msgs.iter().collect();
        let mut want = Vec::new();
        allreduce_intvec(&views, &mut want);
        let mut endpoints = ChannelTransport::mesh(n);
        std::thread::scope(|s| {
            for (ep, msg) in endpoints.iter_mut().zip(&msgs) {
                let want = &want;
                s.spawn(move || {
                    let mut scratch = StagedScratch::default();
                    let mut out = Vec::new();
                    two_level_allreduce_ints(
                        ep, msg, Lanes::I8, 0, group, &mut scratch, &mut out,
                    )
                    .expect("i8 two-level");
                    assert_eq!(&out, want);
                });
            }
        });
    }

    #[test]
    fn block_index_guards_cross_block_frames() {
        // both ranks on block 3: the collective runs normally
        let msg = IntVec::from_i64(&[1, 2, 3, 4], Lanes::I8);
        let mut mesh = ChannelTransport::mesh(2);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        std::thread::scope(|s| {
            let msg_b = msg.clone();
            let h = s.spawn(move || {
                let mut scratch = StagedScratch::default();
                scratch.set_block(3);
                let mut out = Vec::new();
                ring_allreduce_ints(&mut b, &msg_b, Lanes::I8, 0, &mut scratch, &mut out)
                    .expect("same-block ranks agree");
                (out, b)
            });
            let mut scratch = StagedScratch::default();
            scratch.set_block(3);
            let mut out = Vec::new();
            ring_allreduce_ints(&mut a, &msg, Lanes::I8, 0, &mut scratch, &mut out)
                .expect("same-block ranks agree");
            let (out_b, mut b) = h.join().unwrap();
            assert_eq!(out, out_b);
            assert_eq!(out, vec![2, 4, 6, 8]);
            // ranks disagreeing on the block index: the stray frame can
            // never satisfy the guard — typed Replay, not a wrong sum
            let msg_b = msg.clone();
            let h = s.spawn(move || {
                let mut scratch = StagedScratch::default();
                scratch.set_block(4);
                let mut out = Vec::new();
                let e = ring_allreduce_ints(
                    &mut b, &msg_b, Lanes::I8, 1, &mut scratch, &mut out,
                )
                .expect_err("cross-block frame must be rejected");
                assert!(matches!(e, NetError::Replay { .. }), "{e}");
            });
            let mut scratch = StagedScratch::default();
            scratch.set_block(5);
            let mut out = Vec::new();
            let e = ring_allreduce_ints(&mut a, &msg, Lanes::I8, 1, &mut scratch, &mut out)
                .expect_err("cross-block frame must be rejected");
            assert!(matches!(e, NetError::Replay { .. }), "{e}");
            h.join().unwrap();
        });
    }

    #[test]
    fn i8_wire_carries_clipped_partials() {
        // IntSGD's invariant: per-rank |v| <= clip = floor(127 / n) keeps
        // every partial sum in i8 — the staged ring must accept that wire
        let n = 4;
        let d = 100;
        let clip = 127 / n as i64;
        let mut rng = Rng::new(5);
        let msgs: Vec<IntVec> = (0..n)
            .map(|_| {
                let vals: Vec<i64> =
                    (0..d).map(|_| rng.below(2 * clip as u64 + 1) as i64 - clip).collect();
                IntVec::from_i64(&vals, Lanes::I8)
            })
            .collect();
        let views: Vec<&IntVec> = msgs.iter().collect();
        let mut want = Vec::new();
        allreduce_intvec(&views, &mut want);
        assert_eq!(partial_sum_lanes(msgs.iter()), Lanes::I8);

        let mut endpoints = ChannelTransport::mesh(n);
        std::thread::scope(|s| {
            for (ep, msg) in endpoints.iter_mut().zip(&msgs) {
                let want = &want;
                s.spawn(move || {
                    let mut scratch = StagedScratch::default();
                    let mut out = Vec::new();
                    ring_allreduce_ints(ep, msg, Lanes::I8, 0, &mut scratch, &mut out)
                        .expect("i8 ring");
                    assert_eq!(&out, want);
                });
            }
        });
    }

    #[test]
    fn violated_wire_proof_is_a_loud_error() {
        // partial sums exceeding the claimed lane must fail the pack
        // range check, not wrap into garbage
        let n = 2;
        let msgs: Vec<IntVec> =
            (0..n).map(|_| IntVec::from_i64(&[100i64; 8], Lanes::I8)).collect();
        let mut endpoints = ChannelTransport::mesh(n);
        let errs: Vec<Option<NetError>> = std::thread::scope(|s| {
            let handles: Vec<_> = endpoints
                .iter_mut()
                .zip(&msgs)
                .map(|(ep, msg)| {
                    s.spawn(move || {
                        ep.set_timeout(std::time::Duration::from_millis(200));
                        let mut scratch = StagedScratch::default();
                        let mut out = Vec::new();
                        // claim i8 although the sum reaches 200
                        ring_allreduce_ints(ep, msg, Lanes::I8, 0, &mut scratch, &mut out)
                            .err()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(
            errs.iter().flatten().any(|e| matches!(e, NetError::Corrupt { .. })),
            "overflow went unnoticed: {errs:?}"
        );
    }

    #[test]
    fn allgather_bytes_distributes_every_payload() {
        let n = 5;
        let payloads: Vec<Vec<u8>> = (0..n)
            .map(|r| (0..(10 + 17 * r)).map(|k| (r * 31 + k) as u8).collect())
            .collect();
        let mut endpoints = ChannelTransport::mesh(n);
        std::thread::scope(|s| {
            for (ep, mine) in endpoints.iter_mut().zip(&payloads) {
                let payloads = &payloads;
                s.spawn(move || {
                    let mut scratch = StagedScratch::default();
                    let mut out = Vec::new();
                    for round in 0..2 {
                        ring_allgather_bytes(ep, mine, round, &mut scratch, &mut out)
                            .expect("all-gather");
                        assert_eq!(&out, payloads, "round {round}");
                    }
                });
            }
        });
    }

    #[test]
    fn partial_sum_lanes_is_conservative() {
        let a = IntVec::from_i64(&[100], Lanes::I8);
        let b = IntVec::from_i64(&[100], Lanes::I8);
        // 100 + 100 = 200 does not fit i8
        assert_eq!(partial_sum_lanes([&a, &b]), Lanes::I32);
    }

    #[test]
    fn stale_frames_are_skipped_replays_are_rejected() {
        // hand-drive a 2-rank exchange: rank 1 receives a stale frame
        // (old round id) before the real one — skipped; then a duplicate
        // of the real one — typed Replay error.
        let mut mesh = ChannelTransport::mesh(2);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        let msg = IntVec::from_i64(&[1, 2, 3, 4], Lanes::I8);
        let mut scratch_a = StagedScratch::default();
        let mut out = Vec::new();
        // rank 0 first leaks a round-3 frame (an "aborted attempt"), then
        // runs round 7 for real while rank 1 also runs round 7
        let mut stale = Vec::new();
        pack_partials(&[9, 9], Lanes::I8, &mut scratch_a.payload).unwrap();
        encode_frame(
            FrameHeader { round: 3, seq: 0, kind: PayloadKind::I8, elems: 2 },
            &scratch_a.payload,
            &mut stale,
        );
        a.send(1, &stale).unwrap();
        std::thread::scope(|s| {
            let msg_b = msg.clone();
            let h = s.spawn(move || {
                let mut scratch = StagedScratch::default();
                let mut out = Vec::new();
                ring_allreduce_ints(&mut b, &msg_b, Lanes::I8, 7, &mut scratch, &mut out)
                    .expect("rank 1 must skip the stale frame");
                (scratch.take_skipped(), out, b)
            });
            let msg_a = IntVec::from_i64(&[10, 20, 30, 40], Lanes::I8);
            ring_allreduce_ints(&mut a, &msg_a, Lanes::I8, 7, &mut scratch_a, &mut out)
                .expect("rank 0");
            let (skipped, out_b, mut b) = h.join().unwrap();
            assert_eq!(skipped, 1, "exactly the stale frame is discarded");
            assert_eq!(out, out_b);
            assert_eq!(out, vec![11, 22, 33, 44]);
            // now a duplicate *within* the current round: replayed seq 0
            let mut dup = Vec::new();
            pack_partials(&[5, 5], Lanes::I8, &mut scratch_a.payload).unwrap();
            encode_frame(
                FrameHeader { round: 8, seq: 0, kind: PayloadKind::I8, elems: 2 },
                &scratch_a.payload,
                &mut dup,
            );
            a.send(1, &dup).unwrap();
            a.send(1, &dup).unwrap();
            let mut scratch = StagedScratch::default();
            let mut out_b = Vec::new();
            let e = ring_allreduce_ints(&mut b, &msg, Lanes::I8, 8, &mut scratch, &mut out_b)
                .expect_err("duplicate must be rejected");
            assert!(matches!(e, NetError::Replay { rank: 0, round: 8, .. }), "{e}");
        });
    }
}
