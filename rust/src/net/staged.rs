//! Staged collectives: the real multi-step schedules of ring all-reduce,
//! recursive halving-doubling all-reduce, and ring all-gather, executed
//! over any [`Transport`].
//!
//! **Bit-parity argument.** Every integer collective here computes, per
//! coordinate, a sum of the same n rank values the leader-side fold
//! (`collective::allreduce_intvec`) computes — just associated in the
//! schedule's order instead of rank order. The accumulator is `i64` end
//! to end, the summands are wire-bounded (|aggregate| fits the caller's
//! `wire` lane, so no i64 overflow is reachable), and i64 addition is
//! exactly associative and commutative — therefore any schedule produces
//! the identical bit pattern. `tests/net_parity.rs` pins this over real
//! TCP sockets for the whole compressor zoo.
//!
//! **Wire width of partial sums.** The caller passes the lane every
//! *partial* sum provably fits. For IntSGD this is the aggregate wire
//! type itself: each rank clips to `floor((2^{b-1}-1)/n)`, so any subset
//! of ranks sums within the full-aggregate bound (the paper's wire-fit
//! proof, `IntSgd::local_clip`). `pack_partials` range-checks every
//! element, so a violated proof is a loud decode error, never silent
//! corruption. [`partial_sum_lanes`] derives a safe width from the
//! messages themselves when no proof is at hand.
//!
//! Scratch buffers are taken from a per-call [`StagedScratch`] so a
//! steady-state caller (the [`super::TransportReducer`]) reuses payload /
//! frame / receive buffers across rounds.

use anyhow::{anyhow, Result};

use crate::compress::intvec::{IntVec, Lanes};

use super::frame::{
    add_partials, copy_partials, decode_frame, encode_frame, expect_frame, pack_partials,
    FrameHeader, PayloadKind,
};
use super::Transport;

/// Reused buffers for one endpoint's staged collectives.
#[derive(Default)]
pub struct StagedScratch {
    payload: Vec<u8>,
    frame: Vec<u8>,
    rx: Vec<u8>,
    starts: Vec<usize>,
    /// Halving-doubling step log: (partner, keep_lo, keep_hi, give_lo,
    /// give_hi), replayed in reverse for the all-gather phase.
    steps: Vec<(usize, usize, usize, usize, usize)>,
}

/// Narrowest lane provably holding every partial sum of `msgs` — the sum
/// of per-rank magnitudes bounds any subset's sum. Callers with a
/// stronger proof (IntSGD's clip) pass their wire lane directly.
pub fn partial_sum_lanes<'a, I>(msgs: I) -> Lanes
where
    I: IntoIterator<Item = &'a IntVec>,
{
    let bound: i64 = msgs
        .into_iter()
        .map(|m| m.max_abs())
        .fold(0i64, |acc, x| acc.saturating_add(x));
    Lanes::for_bound(bound)
}

/// Ring all-reduce of one integer message: reduce-scatter over n-1 steps
/// on n chunks, then ring all-gather of the finished chunks. On return
/// `out` holds the exact integer sum over all ranks — bit-identical to
/// `collective::allreduce_intvec` (module docs) — and every rank holds
/// the same vector.
pub fn ring_allreduce_ints(
    t: &mut dyn Transport,
    msg: &IntVec,
    wire: Lanes,
    round: u32,
    scratch: &mut StagedScratch,
    out: &mut Vec<i64>,
) -> Result<()> {
    let n = t.world();
    let r = t.rank();
    let d = msg.len();
    out.clear();
    out.resize(d, 0);
    msg.add_range_to(0, out);
    if n == 1 {
        return Ok(());
    }
    let kind = PayloadKind::of_lanes(wire);
    let right = (r + 1) % n;
    let left = (r + n - 1) % n;
    // chunk c covers starts[c]..starts[c + 1]
    scratch.starts.clear();
    scratch.starts.extend((0..=n).map(|c| c * d / n));

    // reduce-scatter: at step s, send accumulated chunk (r - s) right,
    // fold received chunk (r - 1 - s) from the left
    for s in 0..n - 1 {
        let send_c = (r + n - s) % n;
        let recv_c = (r + 2 * n - 1 - s) % n;
        let (slo, shi) = (scratch.starts[send_c], scratch.starts[send_c + 1]);
        pack_partials(&out[slo..shi], wire, &mut scratch.payload)?;
        encode_frame(
            FrameHeader { round, kind, elems: (shi - slo) as u32 },
            &scratch.payload,
            &mut scratch.frame,
        );
        t.send(right, &scratch.frame)?;
        t.recv(left, &mut scratch.rx)?;
        let (rlo, rhi) = (scratch.starts[recv_c], scratch.starts[recv_c + 1]);
        let body = expect_frame(&scratch.rx, round, kind, rhi - rlo)?;
        add_partials(body, wire, &mut out[rlo..rhi])?;
    }
    // all-gather: rank r owns the finished chunk (r + 1); circulate the
    // finished chunks around the ring
    for s in 0..n - 1 {
        let send_c = (r + 1 + n - s) % n;
        let recv_c = (r + n - s) % n;
        let (slo, shi) = (scratch.starts[send_c], scratch.starts[send_c + 1]);
        pack_partials(&out[slo..shi], wire, &mut scratch.payload)?;
        encode_frame(
            FrameHeader { round, kind, elems: (shi - slo) as u32 },
            &scratch.payload,
            &mut scratch.frame,
        );
        t.send(right, &scratch.frame)?;
        t.recv(left, &mut scratch.rx)?;
        let (rlo, rhi) = (scratch.starts[recv_c], scratch.starts[recv_c + 1]);
        let body = expect_frame(&scratch.rx, round, kind, rhi - rlo)?;
        copy_partials(body, wire, &mut out[rlo..rhi])?;
    }
    Ok(())
}

/// Recursive halving-doubling all-reduce (Rabenseifner): reduce-scatter
/// by vector halving with doubling distances, then all-gather by vector
/// doubling — log2(n) rounds of half-sized exchanges instead of the
/// ring's n-1 chunk hops, the latency-optimal schedule for small
/// messages. Requires a power-of-two world; other sizes fall back to the
/// ring schedule (same bits either way — module docs).
pub fn halving_allreduce_ints(
    t: &mut dyn Transport,
    msg: &IntVec,
    wire: Lanes,
    round: u32,
    scratch: &mut StagedScratch,
    out: &mut Vec<i64>,
) -> Result<()> {
    let n = t.world();
    if !n.is_power_of_two() {
        return ring_allreduce_ints(t, msg, wire, round, scratch, out);
    }
    let r = t.rank();
    let d = msg.len();
    out.clear();
    out.resize(d, 0);
    msg.add_range_to(0, out);
    if n == 1 {
        return Ok(());
    }
    let kind = PayloadKind::of_lanes(wire);

    // reduce-scatter: each step, partner pairs split their common segment;
    // each sends the half it gives up and folds the half it keeps
    scratch.steps.clear();
    let (mut lo, mut hi) = (0usize, d);
    let mut dist = n / 2;
    while dist >= 1 {
        let partner = r ^ dist;
        let mid = lo + (hi - lo) / 2;
        let (keep, give) = if r & dist == 0 {
            ((lo, mid), (mid, hi))
        } else {
            ((mid, hi), (lo, mid))
        };
        pack_partials(&out[give.0..give.1], wire, &mut scratch.payload)?;
        encode_frame(
            FrameHeader { round, kind, elems: (give.1 - give.0) as u32 },
            &scratch.payload,
            &mut scratch.frame,
        );
        t.send(partner, &scratch.frame)?;
        t.recv(partner, &mut scratch.rx)?;
        let body = expect_frame(&scratch.rx, round, kind, keep.1 - keep.0)?;
        add_partials(body, wire, &mut out[keep.0..keep.1])?;
        scratch.steps.push((partner, keep.0, keep.1, give.0, give.1));
        lo = keep.0;
        hi = keep.1;
        dist /= 2;
    }
    // all-gather: replay in reverse; I own my keep segment fully summed,
    // the partner owns the give segment — exchange to own their union
    for step in (0..scratch.steps.len()).rev() {
        let (partner, klo, khi, glo, ghi) = scratch.steps[step];
        pack_partials(&out[klo..khi], wire, &mut scratch.payload)?;
        encode_frame(
            FrameHeader { round, kind, elems: (khi - klo) as u32 },
            &scratch.payload,
            &mut scratch.frame,
        );
        t.send(partner, &scratch.frame)?;
        t.recv(partner, &mut scratch.rx)?;
        let body = expect_frame(&scratch.rx, round, kind, ghi - glo)?;
        copy_partials(body, wire, &mut out[glo..ghi])?;
    }
    Ok(())
}

/// Ring all-gather of opaque codec payloads (sparse / sign / QSGD /
/// NatSGD byte streams from `compress::wire`): after n-1 steps every rank
/// holds every rank's bytes. `out[i]` receives rank i's payload into a
/// reused buffer; payload sizes may differ per rank (the header carries
/// each frame's own length).
pub fn ring_allgather_bytes(
    t: &mut dyn Transport,
    mine: &[u8],
    round: u32,
    scratch: &mut StagedScratch,
    out: &mut Vec<Vec<u8>>,
) -> Result<()> {
    let n = t.world();
    let r = t.rank();
    out.resize_with(n, Vec::new);
    out[r].clear();
    out[r].extend_from_slice(mine);
    if n == 1 {
        return Ok(());
    }
    let right = (r + 1) % n;
    let left = (r + n - 1) % n;
    for s in 0..n - 1 {
        let send_origin = (r + n - s) % n;
        let recv_origin = (r + 2 * n - 1 - s) % n;
        let payload = &out[send_origin];
        if payload.len() > u32::MAX as usize {
            return Err(anyhow!("payload too large for a frame"));
        }
        encode_frame(
            FrameHeader {
                round,
                kind: PayloadKind::Bytes,
                elems: payload.len() as u32,
            },
            payload,
            &mut scratch.frame,
        );
        t.send(right, &scratch.frame)?;
        t.recv(left, &mut scratch.rx)?;
        let (h, body) = decode_frame(&scratch.rx)?;
        if h.round != round || h.kind != PayloadKind::Bytes {
            return Err(anyhow!(
                "unexpected frame (round {}, {:?}) during all-gather round {round}",
                h.round,
                h.kind
            ));
        }
        let dst = &mut out[recv_origin];
        dst.clear();
        dst.extend_from_slice(body);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::ChannelTransport;
    use super::*;
    use crate::collective::allreduce_intvec;
    use crate::util::Rng;

    type Staged = fn(
        &mut dyn Transport,
        &IntVec,
        Lanes,
        u32,
        &mut StagedScratch,
        &mut Vec<i64>,
    ) -> Result<()>;

    /// Run one staged all-reduce across n threads and require every
    /// rank's result to be bit-identical to the leader-side fold.
    fn assert_staged_matches_fold(algo: Staged, n: usize, d: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let msgs: Vec<IntVec> = (0..n)
            .map(|_| {
                let vals: Vec<i64> =
                    (0..d).map(|_| rng.below(255) as i64 - 127).collect();
                IntVec::from_i64(&vals, Lanes::I32)
            })
            .collect();
        let views: Vec<&IntVec> = msgs.iter().collect();
        let mut want = Vec::new();
        allreduce_intvec(&views, &mut want);
        let wire = partial_sum_lanes(msgs.iter());

        let mut endpoints = ChannelTransport::mesh(n);
        let results: Vec<Vec<i64>> = std::thread::scope(|s| {
            let handles: Vec<_> = endpoints
                .iter_mut()
                .zip(&msgs)
                .map(|(ep, msg)| {
                    s.spawn(move || {
                        let mut scratch = StagedScratch::default();
                        let mut out = Vec::new();
                        // two rounds over the same endpoints: scratch and
                        // sequencing must survive reuse
                        for round in 0..2 {
                            algo(ep, msg, wire, round, &mut scratch, &mut out)
                                .expect("staged all-reduce");
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, got) in results.iter().enumerate() {
            assert_eq!(got, &want, "rank {rank} (n={n}, d={d})");
        }
    }

    #[test]
    fn ring_matches_leader_fold() {
        for (n, d) in [(1usize, 40usize), (2, 64), (3, 65), (4, 7), (5, 1000), (8, 0)] {
            assert_staged_matches_fold(ring_allreduce_ints, n, d, 11 + n as u64);
        }
    }

    #[test]
    fn halving_matches_leader_fold() {
        // power-of-two worlds take the halving schedule; 3 and 5 exercise
        // the documented ring fallback
        for (n, d) in [(1usize, 16usize), (2, 33), (4, 100), (8, 257), (3, 50), (5, 64)] {
            assert_staged_matches_fold(halving_allreduce_ints, n, d, 77 + n as u64);
        }
    }

    #[test]
    fn i8_wire_carries_clipped_partials() {
        // IntSGD's invariant: per-rank |v| <= clip = floor(127 / n) keeps
        // every partial sum in i8 — the staged ring must accept that wire
        let n = 4;
        let d = 100;
        let clip = 127 / n as i64;
        let mut rng = Rng::new(5);
        let msgs: Vec<IntVec> = (0..n)
            .map(|_| {
                let vals: Vec<i64> =
                    (0..d).map(|_| rng.below(2 * clip as u64 + 1) as i64 - clip).collect();
                IntVec::from_i64(&vals, Lanes::I8)
            })
            .collect();
        let views: Vec<&IntVec> = msgs.iter().collect();
        let mut want = Vec::new();
        allreduce_intvec(&views, &mut want);
        assert_eq!(partial_sum_lanes(msgs.iter()), Lanes::I8);

        let mut endpoints = ChannelTransport::mesh(n);
        std::thread::scope(|s| {
            for (ep, msg) in endpoints.iter_mut().zip(&msgs) {
                let want = &want;
                s.spawn(move || {
                    let mut scratch = StagedScratch::default();
                    let mut out = Vec::new();
                    ring_allreduce_ints(ep, msg, Lanes::I8, 0, &mut scratch, &mut out)
                        .expect("i8 ring");
                    assert_eq!(&out, want);
                });
            }
        });
    }

    #[test]
    fn violated_wire_proof_is_a_loud_error() {
        // partial sums exceeding the claimed lane must fail the pack
        // range check, not wrap into garbage
        let n = 2;
        let msgs: Vec<IntVec> =
            (0..n).map(|_| IntVec::from_i64(&[100i64; 8], Lanes::I8)).collect();
        let mut endpoints = ChannelTransport::mesh(n);
        let errs: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = endpoints
                .iter_mut()
                .zip(&msgs)
                .map(|(ep, msg)| {
                    s.spawn(move || {
                        let mut scratch = StagedScratch::default();
                        let mut out = Vec::new();
                        // claim i8 although the sum reaches 200
                        ring_allreduce_ints(ep, msg, Lanes::I8, 0, &mut scratch, &mut out)
                            .is_err()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(errs.iter().any(|&e| e), "overflow went unnoticed");
    }

    #[test]
    fn allgather_bytes_distributes_every_payload() {
        let n = 5;
        let payloads: Vec<Vec<u8>> = (0..n)
            .map(|r| (0..(10 + 17 * r)).map(|k| (r * 31 + k) as u8).collect())
            .collect();
        let mut endpoints = ChannelTransport::mesh(n);
        std::thread::scope(|s| {
            for (ep, mine) in endpoints.iter_mut().zip(&payloads) {
                let payloads = &payloads;
                s.spawn(move || {
                    let mut scratch = StagedScratch::default();
                    let mut out = Vec::new();
                    for round in 0..2 {
                        ring_allgather_bytes(ep, mine, round, &mut scratch, &mut out)
                            .expect("all-gather");
                        assert_eq!(&out, payloads, "round {round}");
                    }
                });
            }
        });
    }

    #[test]
    fn partial_sum_lanes_is_conservative() {
        let a = IntVec::from_i64(&[100], Lanes::I8);
        let b = IntVec::from_i64(&[100], Lanes::I8);
        // 100 + 100 = 200 does not fit i8
        assert_eq!(partial_sum_lanes([&a, &b]), Lanes::I32);
    }
}
