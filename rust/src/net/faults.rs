//! [`FaultTransport`]: deterministic, seeded fault injection over any
//! [`Transport`] — the ROADMAP's "as many scenarios as you can imagine"
//! applied to the one scenario production always hits.
//!
//! Every failure mode a real fabric produces is reproducible here, in
//! tier-1, over the in-process [`super::ChannelTransport`] (and equally
//! over TCP):
//!
//! - **drop** — a frame silently vanishes (the receiver's round/seq guard
//!   either detects the gap when the next frame arrives or times out);
//! - **duplicate** — a frame is delivered twice (the guard rejects the
//!   replay with a typed [`NetError::Replay`]);
//! - **corrupt** — one byte of the frame is flipped (checksum / header
//!   validation turns it into [`NetError::Corrupt`], or the round guard
//!   skips/rejects it if the flip lands in the header);
//! - **truncate** — the frame is cut short (framing validation);
//! - **delay** — the frame is held back and delivered after the sender's
//!   next transport op (reordering within a pair → the seq guard);
//! - **kill** — at a chosen collective round or op count the endpoint
//!   *dies*: its inner transport is dropped (peers see the connection
//!   close → [`NetError::PeerDead`]) and every local op fails the same
//!   way, exactly like a rank's process disappearing mid-schedule.
//!
//! Faults are injected on the **send** side from a per-endpoint
//! [`Rng`](crate::util::Rng) stream seeded by `(plan.seed, rank)`, so a
//! chaos run is replayable bit for bit. All probabilistic faults are
//! *recoverable*: the `TransportReducer` retries the collective from the
//! unchanged rank messages, and `tests/chaos.rs` pins that training under
//! injected faults is bitwise-identical to the fault-free run.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use crate::util::Rng;

use super::{NetError, Transport, UNKNOWN_ROUND};

/// When a [`FaultTransport`] endpoint dies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KillAt {
    /// Before sending the first frame whose header round id reaches this
    /// value (collective-attempt granularity: "die during round k").
    Round(u32),
    /// After this many successful transport ops (send + recv combined):
    /// hop granularity within a round.
    Op(u64),
}

/// Per-frame fault probabilities plus the seed of the injection stream.
/// All probabilities default to zero (a transparent wrapper).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// P(frame silently vanishes).
    pub drop_p: f64,
    /// P(frame delivered twice).
    pub dup_p: f64,
    /// P(one byte of the frame flipped).
    pub corrupt_p: f64,
    /// P(frame cut to a strict prefix).
    pub truncate_p: f64,
    /// P(frame held back until the sender's next transport op).
    pub delay_p: f64,
}

impl FaultPlan {
    /// A transparent plan (no probabilistic faults) with the given seed —
    /// the starting point for kill-only scenarios.
    pub fn clean(seed: u64) -> Self {
        FaultPlan { seed, drop_p: 0.0, dup_p: 0.0, corrupt_p: 0.0, truncate_p: 0.0, delay_p: 0.0 }
    }

    fn total_p(&self) -> f64 {
        self.drop_p + self.dup_p + self.corrupt_p + self.truncate_p + self.delay_p
    }
}

/// Injected-fault account of one endpoint (diagnostics + test assertions).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub dropped: u64,
    pub duplicated: u64,
    pub corrupted: u64,
    pub truncated: u64,
    pub delayed: u64,
    /// The endpoint died (the kill schedule fired).
    pub killed: bool,
}

impl FaultStats {
    /// Total frames tampered with.
    pub fn total(&self) -> u64 {
        self.dropped + self.duplicated + self.corrupted + self.truncated + self.delayed
    }
}

/// Deterministic fault-injecting wrapper over any [`Transport`].
pub struct FaultTransport<T: Transport> {
    /// `None` once killed — dropping the inner transport is what makes
    /// the death visible to peers (channels disconnect, sockets close).
    inner: Option<T>,
    rank: usize,
    world: usize,
    plan: FaultPlan,
    kill: Option<KillAt>,
    rng: Rng,
    /// Successful transport ops so far (the clock for [`KillAt::Op`]).
    ops: u64,
    /// Held-back frames (destination, frame), flushed on the next op.
    delayed: Vec<(usize, Vec<u8>)>,
    stats: FaultStats,
}

/// Round id of a frame (the first 4 header bytes), for [`KillAt::Round`].
fn frame_round(frame: &[u8]) -> u32 {
    if frame.len() >= 4 {
        u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]])
    } else {
        UNKNOWN_ROUND
    }
}

impl<T: Transport> FaultTransport<T> {
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        for p in [plan.drop_p, plan.dup_p, plan.corrupt_p, plan.truncate_p, plan.delay_p] {
            assert!((0.0..=1.0).contains(&p), "fault probability {p} outside [0, 1]");
        }
        assert!(
            plan.total_p() <= 1.0,
            "fault probabilities sum to {} > 1: the cumulative-threshold draw \
             would starve the later fault kinds",
            plan.total_p()
        );
        let rank = inner.rank();
        let world = inner.world();
        let rng = Rng::new(plan.seed ^ (rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        FaultTransport {
            inner: Some(inner),
            rank,
            world,
            plan,
            kill: None,
            rng,
            ops: 0,
            delayed: Vec::new(),
            stats: FaultStats::default(),
        }
    }

    /// Schedule this endpoint's death.
    pub fn kill_at(mut self, at: KillAt) -> Self {
        self.kill = Some(at);
        self
    }

    /// Wrap a whole mesh; `kill` optionally names one rank and its death
    /// schedule. Endpoint r draws its fault stream from `(plan.seed, r)`.
    pub fn wrap_mesh(
        endpoints: Vec<T>,
        plan: &FaultPlan,
        kill: Option<(usize, KillAt)>,
    ) -> Vec<FaultTransport<T>> {
        endpoints
            .into_iter()
            .map(|ep| {
                let rank = ep.rank();
                let ft = FaultTransport::new(ep, plan.clone());
                match kill {
                    Some((r, at)) if r == rank => ft.kill_at(at),
                    _ => ft,
                }
            })
            .collect()
    }

    pub fn stats(&self) -> &FaultStats {
        &self.stats
    }

    /// Whether the kill schedule has fired.
    pub fn is_killed(&self) -> bool {
        self.inner.is_none()
    }

    fn dead(&self) -> NetError {
        NetError::PeerDead { rank: self.rank, round: UNKNOWN_ROUND }
    }

    /// Drop the inner transport: peers observe the closed connections.
    fn die(&mut self) -> NetError {
        self.inner = None;
        self.delayed.clear();
        self.stats.killed = true;
        self.dead()
    }

    /// Fire the kill schedule if its clock has struck.
    fn check_kill(&mut self, sending_round: Option<u32>) -> Result<(), NetError> {
        if self.inner.is_none() {
            return Err(self.dead());
        }
        match self.kill {
            Some(KillAt::Round(at)) => {
                if let Some(round) = sending_round {
                    if round != UNKNOWN_ROUND && round >= at {
                        return Err(self.die());
                    }
                }
            }
            Some(KillAt::Op(at)) => {
                if self.ops >= at {
                    return Err(self.die());
                }
            }
            None => {}
        }
        Ok(())
    }

    /// Deliver frames held back by earlier delay faults (no re-faulting:
    /// a delayed frame is tampered with once).
    fn flush_delayed(&mut self) -> Result<(), NetError> {
        if self.delayed.is_empty() {
            return Ok(());
        }
        // only reachable alive (check_kill ran first), but a typed error
        // beats a panic if that ordering ever breaks
        let Some(inner) = self.inner.as_mut() else {
            return Err(NetError::PeerDead { rank: self.rank, round: UNKNOWN_ROUND });
        };
        for (to, frame) in std::mem::take(&mut self.delayed) {
            inner.send(to, &frame)?;
        }
        Ok(())
    }
}

impl<T: Transport> Transport for FaultTransport<T> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&mut self, to: usize, frame: &[u8]) -> Result<(), NetError> {
        self.check_kill(Some(frame_round(frame)))?;
        // pick at most one fault per frame from a single uniform draw so
        // the injection stream stays deterministic and replayable
        let u = if self.plan.total_p() > 0.0 { self.rng.uniform() } else { 1.0 };
        let t_drop = self.plan.drop_p;
        let t_dup = t_drop + self.plan.dup_p;
        let t_corrupt = t_dup + self.plan.corrupt_p;
        let t_truncate = t_corrupt + self.plan.truncate_p;
        let t_delay = t_truncate + self.plan.delay_p;
        let Some(inner) = self.inner.as_mut() else {
            return Err(NetError::PeerDead { rank: self.rank, round: UNKNOWN_ROUND });
        };
        if u < t_drop {
            self.stats.dropped += 1;
            crate::telemetry::m::FAULTS_INJECTED.inc();
        } else if u < t_dup {
            self.stats.duplicated += 1;
            crate::telemetry::m::FAULTS_INJECTED.inc();
            inner.send(to, frame)?;
            inner.send(to, frame)?;
        } else if u < t_corrupt {
            self.stats.corrupted += 1;
            crate::telemetry::m::FAULTS_INJECTED.inc();
            let mut bad = frame.to_vec();
            if !bad.is_empty() {
                let at = self.rng.usize_below(bad.len());
                let bit = 1u8 << self.rng.below(8);
                bad[at] ^= bit;
            }
            inner.send(to, &bad)?;
        } else if u < t_truncate {
            self.stats.truncated += 1;
            crate::telemetry::m::FAULTS_INJECTED.inc();
            let keep = self.rng.usize_below(frame.len().max(1));
            inner.send(to, &frame[..keep])?;
        } else if u < t_delay {
            // hold the frame back; it leaves on the NEXT transport op,
            // after whatever that op ships — a reorder within the pair
            self.stats.delayed += 1;
            crate::telemetry::m::FAULTS_INJECTED.inc();
            self.delayed.push((to, frame.to_vec()));
            self.ops += 1;
            return Ok(());
        } else {
            inner.send(to, frame)?;
        }
        self.ops += 1;
        self.flush_delayed()
    }

    fn recv(&mut self, from: usize, out: &mut Vec<u8>) -> Result<(), NetError> {
        self.check_kill(None)?;
        self.flush_delayed()?;
        let Some(inner) = self.inner.as_mut() else {
            return Err(NetError::PeerDead { rank: self.rank, round: UNKNOWN_ROUND });
        };
        let r = inner.recv(from, out);
        if r.is_ok() {
            self.ops += 1;
        }
        r
    }

    fn set_timeout(&mut self, timeout: Duration) {
        if let Some(inner) = self.inner.as_mut() {
            inner.set_timeout(timeout);
        }
    }

    fn set_abort(&mut self, flag: Arc<AtomicBool>) {
        if let Some(inner) = self.inner.as_mut() {
            inner.set_abort(flag);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::frame::{encode_frame, FrameHeader, PayloadKind};
    use super::super::ChannelTransport;
    use super::*;

    fn frame_bytes(round: u32, seq: u32, payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        encode_frame(
            FrameHeader {
                round,
                seq,
                kind: PayloadKind::Bytes,
                elems: payload.len() as u32,
            },
            payload,
            &mut buf,
        );
        buf
    }

    #[test]
    fn clean_plan_is_transparent() {
        let mesh = ChannelTransport::mesh(2);
        let mut wrapped = FaultTransport::wrap_mesh(mesh, &FaultPlan::clean(7), None);
        let b = wrapped.pop().unwrap();
        let mut a = wrapped.pop().unwrap();
        let f = frame_bytes(0, 0, &[1, 2, 3]);
        a.send(1, &f).unwrap();
        let mut b = b;
        let mut rx = Vec::new();
        b.recv(0, &mut rx).unwrap();
        assert_eq!(rx, f);
        assert_eq!(a.stats().total(), 0);
        assert_eq!((a.rank(), a.world()), (0, 2));
    }

    #[test]
    fn fault_stream_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let mesh = ChannelTransport::mesh(2);
            let mut plan = FaultPlan::clean(seed);
            plan.drop_p = 0.3;
            plan.corrupt_p = 0.3;
            let mut wrapped = FaultTransport::wrap_mesh(mesh, &plan, None);
            let _b = wrapped.pop().unwrap();
            let mut a = wrapped.pop().unwrap();
            let f = frame_bytes(0, 0, &[0xAA; 32]);
            for _ in 0..100 {
                a.send(1, &f).unwrap();
            }
            *a.stats()
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12));
        let s = run(11);
        assert!(s.dropped > 0 && s.corrupted > 0, "{s:?}");
    }

    #[test]
    fn dropped_frames_never_arrive_duplicates_arrive_twice() {
        let mesh = ChannelTransport::mesh(2);
        let mut plan = FaultPlan::clean(3);
        plan.drop_p = 1.0;
        let mut wrapped = FaultTransport::wrap_mesh(mesh, &plan, None);
        let mut b = wrapped.pop().unwrap();
        let mut a = wrapped.pop().unwrap();
        a.send(1, &frame_bytes(0, 0, &[1])).unwrap();
        assert_eq!(a.stats().dropped, 1);
        b.set_timeout(Duration::from_millis(20));
        assert!(b.recv(0, &mut Vec::new()).is_err(), "dropped frame arrived");

        let mesh = ChannelTransport::mesh(2);
        let mut plan = FaultPlan::clean(3);
        plan.dup_p = 1.0;
        let mut wrapped = FaultTransport::wrap_mesh(mesh, &plan, None);
        let mut b = wrapped.pop().unwrap();
        let mut a = wrapped.pop().unwrap();
        let f = frame_bytes(0, 0, &[1]);
        a.send(1, &f).unwrap();
        let mut rx = Vec::new();
        b.recv(0, &mut rx).unwrap();
        assert_eq!(rx, f);
        b.recv(0, &mut rx).unwrap();
        assert_eq!(rx, f, "duplicate must be byte-identical");
    }

    #[test]
    fn corrupt_and_truncate_tamper_with_the_bytes() {
        let mesh = ChannelTransport::mesh(2);
        let mut plan = FaultPlan::clean(5);
        plan.corrupt_p = 1.0;
        let mut wrapped = FaultTransport::wrap_mesh(mesh, &plan, None);
        let mut b = wrapped.pop().unwrap();
        let mut a = wrapped.pop().unwrap();
        let f = frame_bytes(0, 0, &[7; 16]);
        a.send(1, &f).unwrap();
        let mut rx = Vec::new();
        b.recv(0, &mut rx).unwrap();
        assert_eq!(rx.len(), f.len());
        assert_ne!(rx, f, "corruption must flip a bit");

        let mesh = ChannelTransport::mesh(2);
        let mut plan = FaultPlan::clean(5);
        plan.truncate_p = 1.0;
        let mut wrapped = FaultTransport::wrap_mesh(mesh, &plan, None);
        let mut b = wrapped.pop().unwrap();
        let mut a = wrapped.pop().unwrap();
        a.send(1, &f).unwrap();
        b.recv(0, &mut rx).unwrap();
        assert!(rx.len() < f.len(), "truncation must shorten the frame");
    }

    #[test]
    fn delayed_frames_reorder_within_the_pair() {
        let mesh = ChannelTransport::mesh(2);
        let mut plan = FaultPlan::clean(9);
        plan.delay_p = 1.0;
        let mut wrapped = FaultTransport::wrap_mesh(mesh, &plan, None);
        let mut b = wrapped.pop().unwrap();
        let mut a = wrapped.pop().unwrap();
        let f0 = frame_bytes(0, 0, &[0]);
        let f1 = frame_bytes(0, 1, &[1]);
        a.send(1, &f0).unwrap(); // held
        // the second send is *also* delayed, but the first flushes behind
        // it — then the second flushes on the next op: force it with a
        // no-fault op by disabling delays
        a.plan.delay_p = 0.0;
        a.send(1, &f1).unwrap(); // delivered, then f0 flushed after it
        let mut rx = Vec::new();
        b.recv(0, &mut rx).unwrap();
        assert_eq!(rx, f1, "delayed frame must arrive after its successor");
        b.recv(0, &mut rx).unwrap();
        assert_eq!(rx, f0);
        assert_eq!(a.stats().delayed, 1);
    }

    #[test]
    fn kill_at_round_is_peer_dead_for_everyone() {
        let mesh = ChannelTransport::mesh(3);
        let mut wrapped =
            FaultTransport::wrap_mesh(mesh, &FaultPlan::clean(1), Some((2, KillAt::Round(5))));
        let mut c = wrapped.pop().unwrap();
        let mut b = wrapped.pop().unwrap();
        let mut a = wrapped.pop().unwrap();
        // round 4 still flows
        c.send(0, &frame_bytes(4, 0, &[1])).unwrap();
        let mut rx = Vec::new();
        a.recv(2, &mut rx).unwrap();
        // round 5 kills rank 2
        let e = c.send(0, &frame_bytes(5, 0, &[1])).unwrap_err();
        assert_eq!(e.rank(), 2);
        assert!(e.is_peer_dead() && c.is_killed() && c.stats().killed);
        // every later local op fails the same way
        assert!(c.recv(0, &mut rx).unwrap_err().is_peer_dead());
        // peers see the death as a closed connection, attributed to rank 2
        let e = b.recv(2, &mut rx).unwrap_err();
        assert_eq!(e, NetError::PeerDead { rank: 2, round: UNKNOWN_ROUND });
        let e = a.send(2, &frame_bytes(5, 0, &[1])).unwrap_err();
        assert_eq!(e.rank(), 2);
        assert!(e.is_peer_dead());
    }

    #[test]
    fn kill_at_op_counts_transport_ops() {
        let mesh = ChannelTransport::mesh(2);
        let mut wrapped =
            FaultTransport::wrap_mesh(mesh, &FaultPlan::clean(1), Some((0, KillAt::Op(2))));
        let _b = wrapped.pop().unwrap();
        let mut a = wrapped.pop().unwrap();
        a.send(1, &frame_bytes(0, 0, &[1])).unwrap();
        a.send(1, &frame_bytes(0, 1, &[2])).unwrap();
        let e = a.send(1, &frame_bytes(0, 2, &[3])).unwrap_err();
        assert!(e.is_peer_dead());
        assert!(a.is_killed());
    }
}
