//! [`TransportReducer`]: the engine's integer reduce phase executed as a
//! staged collective over a real transport.
//!
//! The third [`Reducer`] implementation next to `SerialReducer` (leader
//! fold) and `PoolReducer` (coordinate-chunked fold): here each rank's
//! message leaves its address space — rank r's endpoint runs the staged
//! schedule on its own thread, exchanging framed byte messages with its
//! peers, and every rank independently materializes the identical
//! aggregate (the collective's defining postcondition; a `debug_assert`
//! cross-checks it). Bit-parity with the in-process folds is inherited
//! from `net::staged` (exact integer associativity) and pinned end to
//! end by `tests/net_parity.rs`.
//!
//! The partial-sum wire width is derived per round from the messages
//! themselves ([`partial_sum_lanes`]): for IntSGD's clipped int8 wire the
//! per-rank magnitudes sum within i8, so the staged schedule ships one
//! byte per coordinate per hop — the byte count the paper's all-reduce
//! argument is about.
//!
//! Rank threads are spawned per call via `std::thread::scope` (the
//! borrowed messages make this sound); at ~10 us per spawn this is noise
//! against real socket time, and the transport path is deliberately NOT
//! part of the zero-allocation guarantee — it is the measured-wire
//! reference the in-process paths are compared against
//! (`RoundBreakdown::comm_measured`). A transport failure panics the
//! round: a training loop must not silently continue on a torn
//! collective.

use std::time::Instant;

use crate::compress::engine::{RankMessages, Reducer};
use crate::compress::intvec::Lanes;

use super::staged::{
    halving_allreduce_ints, partial_sum_lanes, ring_allreduce_ints, StagedScratch,
};
use super::{ChannelTransport, TcpTransport, Transport};

/// Which staged schedule the reducer runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StagedAlgo {
    /// Reduce-scatter + all-gather around the ring (bandwidth-optimal,
    /// the NCCL default the paper's cluster numbers assume).
    Ring,
    /// Recursive halving-doubling (latency-optimal; power-of-two worlds,
    /// ring fallback otherwise).
    Halving,
}

/// Per-rank state the reducer owns across rounds.
struct RankState<T> {
    endpoint: T,
    scratch: StagedScratch,
    /// This rank's aggregate (every rank computes the full vector).
    acc: Vec<i64>,
}

pub struct TransportReducer<T: Transport> {
    ranks: Vec<RankState<T>>,
    algo: StagedAlgo,
    /// Collective-call sequence number, stamped into every frame header.
    round: u32,
    wire_seconds: f64,
    calls: u64,
    last_wire: Option<Lanes>,
}

impl TransportReducer<ChannelTransport> {
    /// An n-rank reducer over in-process channels.
    pub fn channel_mesh(n: usize, algo: StagedAlgo) -> Self {
        Self::new(ChannelTransport::mesh(n), algo)
    }
}

impl TransportReducer<TcpTransport> {
    /// An n-rank reducer over loopback TCP sockets.
    pub fn tcp_loopback(n: usize, algo: StagedAlgo) -> anyhow::Result<Self> {
        Ok(Self::new(TcpTransport::loopback_mesh(n)?, algo))
    }
}

impl<T: Transport> TransportReducer<T> {
    /// Endpoint r becomes rank r's end of every staged collective.
    pub fn new(endpoints: Vec<T>, algo: StagedAlgo) -> Self {
        assert!(!endpoints.is_empty(), "at least one endpoint");
        for (r, ep) in endpoints.iter().enumerate() {
            assert_eq!(ep.rank(), r, "endpoint order must match rank order");
        }
        TransportReducer {
            ranks: endpoints
                .into_iter()
                .map(|endpoint| RankState {
                    endpoint,
                    scratch: StagedScratch::default(),
                    acc: Vec::new(),
                })
                .collect(),
            algo,
            round: 0,
            wire_seconds: 0.0,
            calls: 0,
            last_wire: None,
        }
    }

    pub fn world(&self) -> usize {
        self.ranks.len()
    }

    pub fn algo(&self) -> StagedAlgo {
        self.algo
    }

    /// Wall-clock seconds spent inside staged collectives since the last
    /// [`TransportReducer::take_wire_seconds`] — the *measured* side of
    /// `netsim`'s measured-vs-modeled comparison.
    pub fn wire_seconds(&self) -> f64 {
        self.wire_seconds
    }

    /// Read and reset the measured wire time (drivers call this once per
    /// training round to attribute socket time round by round).
    pub fn take_wire_seconds(&mut self) -> f64 {
        std::mem::take(&mut self.wire_seconds)
    }

    /// Staged collectives executed so far.
    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// Wire width the last collective shipped its partial sums at.
    pub fn last_wire(&self) -> Option<Lanes> {
        self.last_wire
    }
}

impl<T: Transport> Reducer for TransportReducer<T> {
    fn sum_ints(&mut self, msgs: &RankMessages, out: &mut Vec<i64>) {
        let n = self.ranks.len();
        assert!(!msgs.is_empty(), "at least one rank message");
        assert_eq!(msgs.len(), n, "one transport endpoint per rank");
        let d = msgs.get(0).as_ints().len();
        for m in msgs.iter() {
            assert_eq!(m.as_ints().len(), d, "mismatched message lengths");
        }
        // Narrowest width every partial sum provably fits: for IntSGD's
        // clipped messages this recovers the aggregate wire type itself.
        let wire = partial_sum_lanes(msgs.iter().map(|m| m.as_ints()));
        self.last_wire = Some(wire);
        let round = self.round;
        self.round = self.round.wrapping_add(1);
        let algo = self.algo;

        let t0 = Instant::now();
        std::thread::scope(|s| {
            for (rank, state) in self.ranks.iter_mut().enumerate() {
                let msg = msgs.get(rank).as_ints();
                s.spawn(move || {
                    let run = match algo {
                        StagedAlgo::Ring => ring_allreduce_ints,
                        StagedAlgo::Halving => halving_allreduce_ints,
                    };
                    run(
                        &mut state.endpoint,
                        msg,
                        wire,
                        round,
                        &mut state.scratch,
                        &mut state.acc,
                    )
                    .unwrap_or_else(|e| {
                        panic!("staged reduce failed on rank {rank}: {e}")
                    });
                });
            }
        });
        self.wire_seconds += t0.elapsed().as_secs_f64();
        self.calls += 1;

        // every rank holds the identical aggregate; rank 0's is the result
        out.clear();
        out.extend_from_slice(&self.ranks[0].acc);
        debug_assert!(
            self.ranks.iter().all(|r| r.acc == self.ranks[0].acc),
            "ranks disagree on the aggregate — the collective is torn"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::engine::{Message, PassPlan, RankEncoder, SerialReducer};
    use crate::compress::intvec::IntVec;
    use crate::util::Rng;

    struct Fixed {
        msg: Message,
    }

    impl RankEncoder for Fixed {
        fn encode(&mut self, _grad: &[f32], _plan: &PassPlan) {}
        fn message(&self) -> &Message {
            &self.msg
        }
    }

    fn fixed_encoders(n: usize, d: usize, seed: u64) -> Vec<Box<dyn RankEncoder>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let vals: Vec<i64> =
                    (0..d).map(|_| rng.below(15) as i64 - 7).collect();
                Box::new(Fixed { msg: Message::Ints(IntVec::from_i64(&vals, Lanes::I8)) })
                    as Box<dyn RankEncoder>
            })
            .collect()
    }

    #[test]
    fn matches_serial_reducer_over_channels() {
        for algo in [StagedAlgo::Ring, StagedAlgo::Halving] {
            for n in [1usize, 3, 4] {
                let encs = fixed_encoders(n, 129, 3 + n as u64);
                let msgs = RankMessages::new(&encs);
                let mut want = Vec::new();
                SerialReducer.sum_ints(&msgs, &mut want);
                let mut red = TransportReducer::channel_mesh(n, algo);
                let mut got = Vec::new();
                // repeated rounds reuse endpoints and scratch
                for _ in 0..3 {
                    red.sum_ints(&msgs, &mut got);
                    assert_eq!(got, want, "{algo:?} n={n}");
                }
                assert_eq!(red.calls(), 3);
                assert!(red.wire_seconds() >= 0.0);
                // |v| <= 7 per rank, so partials fit i8 up to n = 18
                assert_eq!(red.last_wire(), Some(Lanes::I8), "{algo:?} n={n}");
            }
        }
    }

    #[test]
    fn take_wire_seconds_resets() {
        let encs = fixed_encoders(2, 32, 9);
        let msgs = RankMessages::new(&encs);
        let mut red = TransportReducer::channel_mesh(2, StagedAlgo::Ring);
        let mut out = Vec::new();
        red.sum_ints(&msgs, &mut out);
        let t = red.take_wire_seconds();
        assert!(t >= 0.0);
        assert_eq!(red.wire_seconds(), 0.0);
    }

    #[test]
    #[should_panic(expected = "one transport endpoint per rank")]
    fn world_size_mismatch_is_rejected() {
        let encs = fixed_encoders(3, 8, 1);
        let msgs = RankMessages::new(&encs);
        let mut red = TransportReducer::channel_mesh(2, StagedAlgo::Ring);
        let mut out = Vec::new();
        red.sum_ints(&msgs, &mut out);
    }
}
