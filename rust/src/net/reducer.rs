//! [`TransportReducer`]: the engine's integer reduce phase executed as a
//! staged collective over a real transport — with **round-level
//! recovery**.
//!
//! The third [`Reducer`] implementation next to `SerialReducer` (leader
//! fold) and `PoolReducer` (coordinate-chunked fold): here each rank's
//! message leaves its address space — rank r's endpoint runs the staged
//! schedule on its own thread, exchanging framed byte messages with its
//! peers, and every rank independently materializes the identical
//! aggregate (the collective's defining postcondition; a `debug_assert`
//! cross-checks it). Bit-parity with the in-process folds is inherited
//! from `net::staged` (exact integer associativity) and pinned end to
//! end by `tests/net_parity.rs`.
//!
//! **Recovery.** A collective no longer panics or hangs on failure:
//!
//! - *Recoverable* faults (timeouts, corrupt / truncated / replayed
//!   frames — everything [`FaultTransport`](super::FaultTransport)
//!   injects short of a kill) fail the attempt. The first failing rank
//!   raises the shared abort flag so blocked peers bail in milliseconds
//!   ([`NetError::Aborted`]) instead of burning the timeout, and the
//!   whole collective **retries under a fresh round id** — the rank
//!   messages are untouched by the failed attempt, and stale frames from
//!   it are discarded by the round/seq guard, so a retried round is
//!   **bit-identical** to an unfaulted one (`tests/chaos.rs`).
//! - A [`NetError::PeerDead`] is permanent: `sum_ints` returns it, and
//!   the `Coordinator` shrinks the world — [`Reducer::remove_rank`]
//!   re-keys the survivors onto contiguous ranks `0..m` over the same
//!   physical endpoints (dead pairs are simply never addressed again)
//!   and training re-runs the round at the smaller n.
//!
//! The partial-sum wire width is derived per round from the messages
//! themselves ([`partial_sum_lanes`]): for IntSGD's clipped int8 wire the
//! per-rank magnitudes sum within i8, so the staged schedule ships one
//! byte per coordinate per hop — the byte count the paper's all-reduce
//! argument is about.
//!
//! Rank threads are spawned per call via `std::thread::scope` (the
//! borrowed messages make this sound); at ~10 us per spawn this is noise
//! against real socket time, and the transport path is deliberately NOT
//! part of the zero-allocation guarantee — it is the measured-wire
//! reference the in-process paths are compared against
//! (`RoundBreakdown::comm_measured`, which also carries the retry count).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compress::engine::{RankMessages, Reducer};
use crate::compress::intvec::Lanes;
use crate::telemetry::journal::{self, Phase};
use crate::telemetry::m;
use crate::util::cast;

use super::staged::{
    halving_allreduce_ints, partial_sum_lanes, ring_allreduce_ints,
    two_level_allreduce_ints, StagedScratch,
};
use super::{ChannelTransport, NetError, TcpTransport, Transport, UNKNOWN_RANK, UNKNOWN_ROUND};

/// Which staged schedule the reducer runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StagedAlgo {
    /// Reduce-scatter + all-gather around the ring (bandwidth-optimal,
    /// the NCCL default the paper's cluster numbers assume).
    Ring,
    /// Recursive halving-doubling (latency-optimal; power-of-two worlds,
    /// ring fallback otherwise).
    Halving,
    /// Two-level hierarchical: intra-"node" leader fold over groups of
    /// `group` ranks, halving-doubling across the n/group leaders, then
    /// broadcast-down — the schedule that scales past the flat ring's
    /// (n-1)-hop latency wall (degenerate groupings ring-fallback).
    TwoLevel { group: usize },
}

/// Give up after this many retried attempts of one collective (a fault
/// burst longer than this is indistinguishable from a dead fabric).
const DEFAULT_MAX_RETRIES: usize = 8;

/// Per-rank state the reducer owns across rounds.
struct RankState<T> {
    endpoint: T,
    scratch: StagedScratch,
    /// This rank's aggregate (every rank computes the full vector).
    acc: Vec<i64>,
}

/// Survivor-world view of one physical endpoint: the staged schedule runs
/// on contiguous virtual ranks `0..m`; this adapter translates them to the
/// mesh's physical ranks (and failure ranks back to virtual).
struct Remap<'a> {
    inner: &'a mut dyn Transport,
    /// `map[v]` = physical rank of virtual rank v.
    map: &'a [usize],
    vrank: usize,
}

impl Remap<'_> {
    fn to_virtual(&self, e: NetError) -> NetError {
        e.map_rank(|phys| {
            // a physical rank outside the survivor map (e.g. a lingering
            // error about an already-removed peer) must NOT alias a
            // surviving virtual rank — surface it as unattributed
            self.map
                .iter()
                .position(|&p| p == phys)
                .unwrap_or(crate::net::UNKNOWN_RANK)
        })
    }
}

impl Transport for Remap<'_> {
    fn rank(&self) -> usize {
        self.vrank
    }

    fn world(&self) -> usize {
        self.map.len()
    }

    fn send(&mut self, to: usize, frame: &[u8]) -> Result<(), NetError> {
        let phys = self.map[to];
        self.inner.send(phys, frame).map_err(|e| self.to_virtual(e))
    }

    fn recv(&mut self, from: usize, out: &mut Vec<u8>) -> Result<(), NetError> {
        let phys = self.map[from];
        self.inner.recv(phys, out).map_err(|e| self.to_virtual(e))
    }
}

pub struct TransportReducer<T: Transport> {
    /// Survivor states, indexed by virtual rank.
    ranks: Vec<RankState<T>>,
    /// Virtual -> physical rank (identity until a failover).
    map: Vec<usize>,
    algo: StagedAlgo,
    /// Collective-attempt sequence number, stamped into every frame
    /// header. Incremented per **attempt**, not per logical round, so a
    /// retry runs under a fresh id and stale frames are skippable.
    round: u32,
    wire_seconds: f64,
    calls: u64,
    retries: u64,
    stale_skipped: u64,
    max_retries: usize,
    last_wire: Option<Lanes>,
    /// Pipeline block index of the *next* collective, stamped into every
    /// rank's frame seqs ([`Reducer::begin_block`]); reset to 0 after each
    /// `sum_ints` so barrier-path collectives always run as block 0.
    block: u32,
    abort: Arc<AtomicBool>,
    /// High-water marks of `wire_seconds`/`retries` at the last
    /// [`Reducer::take_wire_measure`] — per-round deltas for the observer
    /// breakdown without resetting the cumulative counters the tests and
    /// summary reports read.
    wire_mark: f64,
    retries_mark: u64,
}

impl TransportReducer<ChannelTransport> {
    /// An n-rank reducer over in-process channels.
    pub fn channel_mesh(n: usize, algo: StagedAlgo) -> Self {
        Self::new(ChannelTransport::mesh(n), algo)
    }
}

impl TransportReducer<TcpTransport> {
    /// An n-rank reducer over loopback TCP sockets.
    pub fn tcp_loopback(n: usize, algo: StagedAlgo) -> anyhow::Result<Self> {
        Ok(Self::new(TcpTransport::loopback_mesh(n)?, algo))
    }
}

impl<T: Transport> TransportReducer<T> {
    /// Endpoint r becomes rank r's end of every staged collective.
    // intlint: allow(R2, reason="constructor: per-rank state is built once, before the round loop")
    pub fn new(endpoints: Vec<T>, algo: StagedAlgo) -> Self {
        assert!(!endpoints.is_empty(), "at least one endpoint");
        for (r, ep) in endpoints.iter().enumerate() {
            assert_eq!(ep.rank(), r, "endpoint order must match rank order");
        }
        let abort = Arc::new(AtomicBool::new(false));
        let map = (0..endpoints.len()).collect();
        TransportReducer {
            ranks: endpoints
                .into_iter()
                .map(|mut endpoint| {
                    endpoint.set_abort(Arc::clone(&abort));
                    RankState {
                        endpoint,
                        scratch: StagedScratch::default(),
                        acc: Vec::new(),
                    }
                })
                .collect(),
            map,
            algo,
            round: 0,
            wire_seconds: 0.0,
            calls: 0,
            retries: 0,
            stale_skipped: 0,
            max_retries: DEFAULT_MAX_RETRIES,
            last_wire: None,
            block: 0,
            abort,
            wire_mark: 0.0,
            retries_mark: 0,
        }
    }

    /// Surviving world size.
    pub fn world(&self) -> usize {
        self.ranks.len()
    }

    pub fn algo(&self) -> StagedAlgo {
        self.algo
    }

    /// Bound every endpoint's blocking sends/receives (see
    /// `Transport::set_timeout`; env default `INTSGD_NET_TIMEOUT_MS`).
    pub fn set_timeout(&mut self, timeout: Duration) {
        for state in &mut self.ranks {
            state.endpoint.set_timeout(timeout);
        }
    }

    /// Cap on retried attempts per collective (default 8).
    pub fn set_max_retries(&mut self, max: usize) {
        self.max_retries = max;
    }

    /// Wall-clock seconds spent inside staged collectives since the last
    /// [`TransportReducer::take_wire_seconds`] — the *measured* side of
    /// `netsim`'s measured-vs-modeled comparison. Includes retried
    /// attempts: a fault costs real wire time.
    pub fn wire_seconds(&self) -> f64 {
        self.wire_seconds
    }

    /// Read and reset the measured wire time (drivers call this once per
    /// training round to attribute socket time round by round).
    pub fn take_wire_seconds(&mut self) -> f64 {
        self.wire_mark = 0.0;
        std::mem::take(&mut self.wire_seconds)
    }

    /// Staged collectives executed so far (logical, not attempts).
    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// Retried attempts so far (fault/retry accounting; netsim's
    /// `RoundBreakdown::comm_retries`).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Read and reset the retry counter (per-round attribution).
    pub fn take_retries(&mut self) -> u64 {
        self.retries_mark = 0;
        std::mem::take(&mut self.retries)
    }

    /// Stale frames discarded by the round/seq guard so far (leftovers of
    /// aborted attempts — nonzero only after retries).
    pub fn stale_skipped(&self) -> u64 {
        self.stale_skipped
    }

    /// Wire width the last collective shipped its partial sums at.
    pub fn last_wire(&self) -> Option<Lanes> {
        self.last_wire
    }

    /// One attempt of the collective across all survivor threads; returns
    /// every rank failure (empty = success).
    // intlint: allow(R2, R4, reason="scoped-thread fan-out: spawn/join allocate per attempt (documented off the zero-alloc path), and a panicked rank thread is propagated, not handled")
    fn attempt(&mut self, msgs: &RankMessages, wire: Lanes, round: u32) -> Vec<NetError> {
        self.abort.store(false, Ordering::Relaxed);
        let algo = self.algo;
        let block = self.block;
        let map = &self.map;
        let abort = &self.abort;
        let errs: Vec<Option<NetError>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .ranks
                .iter_mut()
                .enumerate()
                .map(|(vrank, state)| {
                    let msg = msgs.ints(vrank);
                    state.scratch.set_block(block);
                    s.spawn(move || {
                        let mut t = Remap {
                            inner: &mut state.endpoint,
                            map,
                            vrank,
                        };
                        let span_t = journal::start();
                        let r = match algo {
                            StagedAlgo::Ring => ring_allreduce_ints(
                                &mut t,
                                msg,
                                wire,
                                round,
                                &mut state.scratch,
                                &mut state.acc,
                            ),
                            StagedAlgo::Halving => halving_allreduce_ints(
                                &mut t,
                                msg,
                                wire,
                                round,
                                &mut state.scratch,
                                &mut state.acc,
                            ),
                            StagedAlgo::TwoLevel { group } => two_level_allreduce_ints(
                                &mut t,
                                msg,
                                wire,
                                round,
                                group,
                                &mut state.scratch,
                                &mut state.acc,
                            ),
                        };
                        // one span per rank leg of the collective — in the
                        // trace these are the per-rank lanes under the
                        // leader's reduce span
                        journal::record(
                            Phase::Reduce,
                            round,
                            cast::sat_u16(cast::usize_from(block)),
                            cast::sat_u16(vrank),
                            span_t,
                        );
                        if r.is_err() {
                            // wake every peer blocked on this round
                            abort.store(true, Ordering::Relaxed);
                        }
                        r.err()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        errs.into_iter().flatten().collect()
    }
}

/// The most diagnostic error of a failed attempt: the root cause, not the
/// cascade — peers that merely bailed out rank last. An empty input
/// (never produced by a failed attempt) degrades to an unattributed
/// `Aborted` rather than a panic.
fn primary_error(errs: Vec<NetError>) -> NetError {
    fn severity(e: &NetError) -> u8 {
        match e {
            NetError::PeerDead { .. } => 4,
            NetError::Corrupt { .. } => 3,
            NetError::Replay { .. } => 2,
            NetError::Timeout { .. } => 1,
            NetError::Aborted { .. } => 0,
        }
    }
    errs.into_iter()
        .max_by_key(severity)
        .unwrap_or(NetError::Aborted { rank: UNKNOWN_RANK, round: UNKNOWN_ROUND })
}

impl<T: Transport> Reducer for TransportReducer<T> {
    fn sum_ints(&mut self, msgs: &RankMessages, out: &mut Vec<i64>) -> Result<(), NetError> {
        let m = self.ranks.len();
        assert!(!msgs.is_empty(), "at least one rank message");
        assert_eq!(msgs.len(), m, "one transport endpoint per rank");
        let d = msgs.ints(0).len();
        for msg in msgs.iter_ints() {
            assert_eq!(msg.len(), d, "mismatched message lengths");
        }
        // Narrowest width every partial sum provably fits: for IntSGD's
        // clipped messages this recovers the aggregate wire type itself.
        let wire = partial_sum_lanes(msgs.iter_ints());
        self.last_wire = Some(wire);
        m::WIRE_LANE.bump(wire);

        // Telemetry timing: feeds intsgd_comm_measured_seconds, never
        // round arithmetic (clippy.toml).
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now();
        let mut attempts = 0usize;
        let outcome = loop {
            let round = self.round;
            self.round = self.round.wrapping_add(1);
            let errs = self.attempt(msgs, wire, round);
            if errs.is_empty() {
                break Ok(());
            }
            for e in &errs {
                match e {
                    NetError::Timeout { .. } => m::NET_TIMEOUTS.inc(),
                    NetError::Replay { .. } => m::NET_REPLAYS.inc(),
                    NetError::Corrupt { .. } => m::NET_CORRUPT.inc(),
                    _ => {}
                }
            }
            // a dead *member* cannot be retried away: report it for
            // failover. A death notice about a rank outside the current
            // world (stale noise about an already-removed peer) is
            // retried like any recoverable fault.
            if let Some(dead) = errs.iter().find(|e| e.is_peer_dead() && e.rank() < m) {
                break Err(dead.clone());
            }
            attempts += 1;
            self.retries += 1;
            m::NET_RETRIES.inc();
            if attempts > self.max_retries {
                break Err(primary_error(errs));
            }
            // recoverable: rerun under a fresh round id; the messages are
            // untouched and the seq guard discards this attempt's litter
        };
        self.wire_seconds += t0.elapsed().as_secs_f64();
        self.calls += 1;
        m::NET_COLLECTIVES.inc();
        // the block stamp is per-collective: the next caller re-announces
        // its block (or stays on the barrier path's block 0)
        self.block = 0;
        let stale: u64 = self
            .ranks
            .iter_mut()
            .map(|state| state.scratch.take_skipped())
            .sum();
        self.stale_skipped += stale;
        m::NET_STALE_FRAMES.add(stale);
        outcome?;

        // every rank holds the identical aggregate; rank 0's is the result
        out.clear();
        out.extend_from_slice(&self.ranks[0].acc);
        debug_assert!(
            self.ranks.iter().all(|r| r.acc == self.ranks[0].acc),
            "ranks disagree on the aggregate — the collective is torn"
        );
        Ok(())
    }

    /// Stamp the pipeline block index of the next collective into every
    /// frame's seq high bits ([`crate::net::frame::block_seq`]): a frame
    /// straying between in-flight blocks can never satisfy the guard.
    fn begin_block(&mut self, block: usize) {
        self.block = cast::sat_u32(block);
    }

    /// The measured side of netsim's measured-vs-modeled comparison: this
    /// reducer moves real bytes, so per-round wire wall-clock and retry
    /// counts are attributable (`Network::round_breakdown_net`). Deltas
    /// are tracked against high-water marks, so the cumulative
    /// `wire_seconds()`/`retries()` readers keep their totals.
    fn take_wire_measure(&mut self) -> Option<(f64, u64)> {
        let wire = self.wire_seconds - self.wire_mark;
        let retries = self.retries - self.retries_mark;
        self.wire_mark = self.wire_seconds;
        self.retries_mark = self.retries;
        Some((wire, retries))
    }

    /// Shrink the world to the survivors: drop the dead rank's endpoint
    /// (its connections are already gone) and re-key the remaining
    /// endpoints onto contiguous virtual ranks.
    fn remove_rank(&mut self, rank: usize) {
        assert!(rank < self.ranks.len(), "removing rank {rank} of {}", self.ranks.len());
        assert!(self.ranks.len() > 1, "cannot remove the last rank");
        self.ranks.remove(rank);
        self.map.remove(rank);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::engine::{Message, PassPlan, RankEncoder, SerialReducer};
    use crate::compress::intvec::IntVec;
    use crate::net::{FaultPlan, FaultTransport, KillAt};
    use crate::util::Rng;

    struct Fixed {
        msg: Message,
    }

    impl RankEncoder for Fixed {
        fn encode(&mut self, _grad: &[f32], _plan: &PassPlan) {}
        fn message(&self) -> &Message {
            &self.msg
        }
    }

    fn fixed_encoders(n: usize, d: usize, seed: u64) -> Vec<Box<dyn RankEncoder>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let vals: Vec<i64> =
                    (0..d).map(|_| rng.below(15) as i64 - 7).collect();
                Box::new(Fixed { msg: Message::Ints(IntVec::from_i64(&vals, Lanes::I8)) })
                    as Box<dyn RankEncoder>
            })
            .collect()
    }

    #[test]
    fn matches_serial_reducer_over_channels() {
        for algo in [
            StagedAlgo::Ring,
            StagedAlgo::Halving,
            StagedAlgo::TwoLevel { group: 2 },
        ] {
            for n in [1usize, 3, 4] {
                let encs = fixed_encoders(n, 129, 3 + n as u64);
                let msgs = RankMessages::new(&encs);
                let mut want = Vec::new();
                SerialReducer.sum_ints(&msgs, &mut want).unwrap();
                let mut red = TransportReducer::channel_mesh(n, algo);
                let mut got = Vec::new();
                // repeated rounds reuse endpoints and scratch
                for _ in 0..3 {
                    red.sum_ints(&msgs, &mut got).expect("clean fabric");
                    assert_eq!(got, want, "{algo:?} n={n}");
                }
                assert_eq!(red.calls(), 3);
                assert_eq!(red.retries(), 0);
                assert_eq!(red.stale_skipped(), 0);
                assert!(red.wire_seconds() >= 0.0);
                // |v| <= 7 per rank, so partials fit i8 up to n = 18
                assert_eq!(red.last_wire(), Some(Lanes::I8), "{algo:?} n={n}");
            }
        }
    }

    #[test]
    fn take_wire_seconds_resets() {
        let encs = fixed_encoders(2, 32, 9);
        let msgs = RankMessages::new(&encs);
        let mut red = TransportReducer::channel_mesh(2, StagedAlgo::Ring);
        let mut out = Vec::new();
        red.sum_ints(&msgs, &mut out).unwrap();
        let t = red.take_wire_seconds();
        assert!(t >= 0.0);
        assert_eq!(red.wire_seconds(), 0.0);
    }

    #[test]
    #[should_panic(expected = "one transport endpoint per rank")]
    fn world_size_mismatch_is_rejected() {
        let encs = fixed_encoders(3, 8, 1);
        let msgs = RankMessages::new(&encs);
        let mut red = TransportReducer::channel_mesh(2, StagedAlgo::Ring);
        let mut out = Vec::new();
        let _ = red.sum_ints(&msgs, &mut out);
    }

    #[test]
    fn injected_recoverable_faults_retry_to_the_exact_answer() {
        let n = 4;
        let encs = fixed_encoders(n, 257, 21);
        let msgs = RankMessages::new(&encs);
        let mut want = Vec::new();
        SerialReducer.sum_ints(&msgs, &mut want).unwrap();

        let mut plan = FaultPlan::clean(0xFA17);
        plan.corrupt_p = 0.02;
        plan.dup_p = 0.02;
        plan.truncate_p = 0.01;
        let mesh = FaultTransport::wrap_mesh(ChannelTransport::mesh(n), &plan, None);
        let mut red = TransportReducer::new(mesh, StagedAlgo::Ring);
        red.set_timeout(Duration::from_millis(300));
        red.set_max_retries(64);
        let mut got = Vec::new();
        let mut total_retries = 0;
        for _ in 0..20 {
            red.sum_ints(&msgs, &mut got).expect("faults must be retried away");
            assert_eq!(got, want, "retried collective must be bit-identical");
            total_retries += red.take_retries();
        }
        assert!(total_retries > 0, "the fault plan never fired");
    }

    #[test]
    fn dead_rank_reports_peer_dead_then_survivors_carry_on() {
        let n = 3;
        let encs = fixed_encoders(n, 64, 33);
        let msgs = RankMessages::new(&encs);
        // rank 2 dies on its very first frame
        let mesh = FaultTransport::wrap_mesh(
            ChannelTransport::mesh(n),
            &FaultPlan::clean(1),
            Some((2, KillAt::Round(0))),
        );
        let mut red = TransportReducer::new(mesh, StagedAlgo::Ring);
        red.set_timeout(Duration::from_millis(500));
        let mut out = Vec::new();
        let e = red.sum_ints(&msgs, &mut out).expect_err("the death must surface");
        assert!(e.is_peer_dead(), "{e}");
        assert_eq!(e.rank(), 2);
        // failover: shrink to the survivors and reduce their messages
        red.remove_rank(2);
        assert_eq!(red.world(), 2);
        let surv = fixed_encoders(n, 64, 33).into_iter().take(2).collect::<Vec<_>>();
        let smsgs = RankMessages::new(&surv);
        let mut want = Vec::new();
        SerialReducer.sum_ints(&smsgs, &mut want).unwrap();
        red.sum_ints(&smsgs, &mut out).expect("survivor world must work");
        assert_eq!(out, want);
    }
}
