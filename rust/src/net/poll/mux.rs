//! The multiplexed nonblocking transport: one socket per rank pair,
//! many logical channels, one readiness-polled event loop per rank.
//!
//! Every rank owns a [`MuxIo`] core — the set of per-peer [`Conn`]s
//! plus a reusable poll set — behind an `Arc<Mutex<_>>`. A
//! [`MuxTransport`] is one (rank, channel) endpoint on that core and
//! implements [`Transport`] verbatim, so `TransportReducer` drives it
//! like any dedicated-socket backend: channel 0 of a single-channel
//! mesh is bit-identical to `TcpTransport` (pinned in `tests/serve.rs`)
//! while additional channels carry other jobs' rounds over the same
//! sockets. Blocked operations never spin: after a short yield phase
//! they park in `poll(2)` slices ([`WAIT_SLICE`]) with the core lock
//! released between slices so sibling channels keep making progress.
//!
//! Backpressure is explicit and typed: each (channel, peer) write queue
//! is bounded ([`DEFAULT_QUEUE_FRAMES`] frames, tunable per mesh), and
//! a sender that finds the queue full observes it as
//! [`MuxTransport::try_send`] returning `false` (blocking `send` keeps
//! servicing the loop until space frees or the per-logical-op deadline
//! passes). Every such stall increments `NET_BACKPRESSURE_EVENTS`.

// Wall-clock reads below are the transport deadline machinery — one of
// clippy.toml's allowed zones (net deadlines, telemetry, benches).
#![allow(clippy::disallowed_methods)]

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::super::{default_io_timeout, NetError, Transport, UNKNOWN_RANK, UNKNOWN_ROUND};
use super::conn::Conn;
use super::sys::{self, PollFd, POLLIN, POLLOUT};
use crate::net::tcp::MAX_FRAME_BYTES;
use crate::telemetry::m;
use crate::util::cast;

/// Default bound on frames queued per (channel, peer) before senders
/// observe backpressure.
pub const DEFAULT_QUEUE_FRAMES: usize = 64;

/// Hard cap on logical channels per mesh (the envelope channel word
/// reserves its top bit for the close control).
pub const MAX_CHANNELS: usize = 4096;

/// Loopback mesh size cap — mirrors `TcpTransport::loopback_mesh`.
const MAX_LOOPBACK_RANKS: usize = 64;

/// Fruitless passes before a blocked op parks in poll slices instead of
/// yielding (latency-first at the start, cores-first when idle).
const SPIN_BEFORE_WAIT: u32 = 64;

/// One parked wait: short enough that sibling channels contend for the
/// core lock at sub-millisecond granularity, long enough to stay off
/// the CPU while idle.
const WAIT_SLICE: Duration = Duration::from_millis(1);

/// Per-channel endpoint census shared by one mesh: how many endpoints
/// of each channel are still open, which feeds `MUX_CHANNELS_ACTIVE`
/// (channels with at least one live endpoint in this process).
struct Census {
    counts: Vec<AtomicUsize>,
}

impl Census {
    fn channels_active(&self) -> usize {
        self.counts.iter().filter(|c| c.load(Ordering::Relaxed) > 0).count()
    }
}

/// One rank's event-loop core: per-peer connections plus the reusable
/// poll set. Shared by every channel endpoint of that rank.
struct MuxIo {
    /// Index = peer rank; `None` at this rank's own slot.
    conns: Vec<Option<Conn>>,
    /// Reused poll request buffer (no per-pass allocation).
    pfds: Vec<PollFd>,
}

impl MuxIo {
    /// One event-loop pass: optionally park (≤ `wait`) for readiness,
    /// then flush every writable connection and pump every readable
    /// one. Returns whether any bytes or frames moved. A hostile frame
    /// surfaces `Corrupt` once (attributed to the offending peer) and
    /// poisons that connection; unrelated channels keep running.
    fn service(&mut self, wait: Duration) -> Result<bool, NetError> {
        if !wait.is_zero() {
            self.pfds.clear();
            for conn in self.conns.iter().flatten() {
                if conn.closed {
                    continue;
                }
                let mut events = POLLIN;
                if conn.wants_write() {
                    events |= POLLOUT;
                }
                self.pfds.push(PollFd::new(conn.raw_fd(), events));
            }
            if !self.pfds.is_empty() {
                sys::wait(&mut self.pfds, wait).map_err(|e| NetError::Corrupt {
                    rank: UNKNOWN_RANK,
                    round: UNKNOWN_ROUND,
                    detail: format!("poll: {e}"),
                })?;
            }
        }
        let mut progressed = false;
        for peer in 0..self.conns.len() {
            if let Some(conn) = self.conns[peer].as_mut() {
                progressed |= conn.flush();
                progressed |= conn.pump(peer)?;
            }
        }
        Ok(progressed)
    }
}

/// One (rank, channel) endpoint of a multiplexed mesh. See the module
/// docs for the runtime model; see [`Transport`] for the contract it
/// honors — including per-logical-op deadlines: `set_timeout` bounds
/// each `send`/`recv` call as a whole, never individual syscalls.
pub struct MuxTransport {
    rank: usize,
    world: usize,
    channel: usize,
    queue_cap: usize,
    io: Arc<Mutex<MuxIo>>,
    census: Arc<Census>,
    timeout: Duration,
    abort: Option<Arc<AtomicBool>>,
    open: bool,
}

impl MuxTransport {
    /// A loopback mesh of `n` ranks × `channels` logical channels with
    /// the default queue bound. Returns endpoint vectors indexed
    /// `[channel][rank]` — each inner vector is a rank-ordered mesh
    /// ready for `TransportReducer::new`.
    pub fn loopback_mesh(n: usize, channels: usize) -> Result<Vec<Vec<MuxTransport>>> {
        Self::loopback_mesh_with(n, channels, DEFAULT_QUEUE_FRAMES)
    }

    /// [`MuxTransport::loopback_mesh`] with an explicit per-channel
    /// write-queue bound (`net.mux.queue_frames` on the CLI).
    pub fn loopback_mesh_with(
        n: usize,
        channels: usize,
        queue_frames: usize,
    ) -> Result<Vec<Vec<MuxTransport>>> {
        if n == 0 || n > MAX_LOOPBACK_RANKS {
            return Err(anyhow!("mux loopback mesh wants 1..={MAX_LOOPBACK_RANKS} ranks, got {n}"));
        }
        if channels == 0 || channels > MAX_CHANNELS {
            return Err(anyhow!("mux mesh wants 1..={MAX_CHANNELS} channels, got {channels}"));
        }
        if queue_frames == 0 {
            return Err(anyhow!("net.mux.queue_frames must be at least 1"));
        }
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0").context("bind"))
            .collect::<Result<_>>()?;
        let addrs: Vec<_> = listeners
            .iter()
            .map(|l| l.local_addr().context("listener addr"))
            .collect::<Result<_>>()?;

        let mut conns: Vec<Vec<Option<Conn>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();

        // Dial every pair i < j (the connect completes into j's listen
        // backlog — no concurrent accept loop needed on loopback), then
        // accept and attribute each inbound stream by its hello. Same
        // handshake as TcpTransport::loopback_mesh.
        for i in 0..n {
            for j in i + 1..n {
                let mut stream =
                    TcpStream::connect(addrs[j]).with_context(|| format!("rank {i} -> {j}"))?;
                stream
                    .write_all(&cast::to_u32(i)?.to_le_bytes())
                    .context("send hello")?;
                conns[i][j] = Some(Conn::new(stream, channels)?);
            }
        }
        for (j, listener) in listeners.iter().enumerate() {
            for _ in 0..j {
                let (mut stream, _) = listener.accept().context("accept")?;
                let mut hello = [0u8; 4];
                stream.read_exact(&mut hello).context("read hello")?;
                let i = cast::usize_from(u32::from_le_bytes(hello));
                if i >= n || conns[j][i].is_some() {
                    return Err(anyhow!("bogus hello rank {i} at listener {j}"));
                }
                conns[j][i] = Some(Conn::new(stream, channels)?);
            }
        }

        let census = Arc::new(Census {
            counts: (0..channels).map(|_| AtomicUsize::new(n)).collect(),
        });
        m::MUX_CHANNELS_ACTIVE.set(cast::sat_u32(census.channels_active()).into());

        let cores: Vec<Arc<Mutex<MuxIo>>> = conns
            .into_iter()
            .map(|conns| Arc::new(Mutex::new(MuxIo { conns, pfds: Vec::new() })))
            .collect();

        Ok((0..channels)
            .map(|channel| {
                (0..n)
                    .map(|rank| MuxTransport {
                        rank,
                        world: n,
                        channel,
                        queue_cap: queue_frames,
                        io: Arc::clone(&cores[rank]),
                        census: Arc::clone(&census),
                        timeout: default_io_timeout(),
                        abort: None,
                        open: true,
                    })
                    .collect()
            })
            .collect())
    }

    /// The channel this endpoint multiplexes over.
    pub fn channel(&self) -> usize {
        self.channel
    }

    fn lock_io(&self) -> Result<MutexGuard<'_, MuxIo>, NetError> {
        self.io.lock().map_err(|_| NetError::Corrupt {
            rank: UNKNOWN_RANK,
            round: UNKNOWN_ROUND,
            detail: "mux event loop poisoned by a panicked sibling".to_string(),
        })
    }

    fn aborted(&self) -> bool {
        self.abort.as_ref().is_some_and(|f| f.load(Ordering::Relaxed))
    }

    /// Nonblocking send: stage `frame` on the peer's bounded channel
    /// queue if there is room. `Ok(false)` is typed backpressure — the
    /// queue is full *right now*; the caller decides whether to retry,
    /// park, or shed load. Counted in `NET_BACKPRESSURE_EVENTS`.
    pub fn try_send(&mut self, to: usize, frame: &[u8]) -> Result<bool, NetError> {
        assert!(to != self.rank, "rank {} sending to itself", self.rank);
        if frame.len() > MAX_FRAME_BYTES {
            return Err(NetError::Corrupt {
                rank: to,
                round: UNKNOWN_ROUND,
                detail: format!(
                    "frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte cap",
                    frame.len()
                ),
            });
        }
        let mut io = self.lock_io()?;
        // Keep draining inbound while waiting to send — the progress
        // guarantee every Transport impl honors (deadlock freedom).
        io.service(Duration::ZERO)?;
        let Some(conn) = io.conns.get_mut(to).and_then(|c| c.as_mut()) else {
            return Err(NetError::PeerDead { rank: to, round: UNKNOWN_ROUND });
        };
        if conn.channel_down(self.channel) {
            return Err(NetError::PeerDead { rank: to, round: UNKNOWN_ROUND });
        }
        if conn.pending(self.channel) >= self.queue_cap {
            m::NET_BACKPRESSURE_EVENTS.inc();
            return Ok(false);
        }
        conn.enqueue(self.channel, frame);
        m::MUX_QUEUE_DEPTH.set(self.channel, cast::sat_u32(conn.pending(self.channel)).into());
        conn.flush();
        Ok(true)
    }

    /// Park until the next service pass is warranted: yield for the
    /// first [`SPIN_BEFORE_WAIT`] passes, then hold the core in one
    /// [`WAIT_SLICE`] poll (lock released again before the caller's
    /// next pass, so sibling channels interleave at slice granularity).
    fn wait_pass(&self, spins: &mut u32) -> Result<(), NetError> {
        *spins += 1;
        if *spins <= SPIN_BEFORE_WAIT {
            std::thread::yield_now();
        } else {
            self.lock_io()?.service(WAIT_SLICE)?;
        }
        Ok(())
    }

    /// Announce this endpoint's permanent departure on its channel.
    /// Peers drain frames already queued, then observe `PeerDead` on
    /// this (rank, channel) pair only — sibling channels on the same
    /// sockets are untouched. Idempotent; called on drop.
    fn close(&mut self) {
        if !self.open {
            return;
        }
        self.open = false;
        if let Some(c) = self.census.counts.get(self.channel) {
            c.fetch_sub(1, Ordering::Relaxed);
        }
        m::MUX_CHANNELS_ACTIVE.set(cast::sat_u32(self.census.channels_active()).into());
        if let Ok(mut io) = self.io.lock() {
            for peer in 0..io.conns.len() {
                if let Some(conn) = io.conns[peer].as_mut() {
                    conn.enqueue_close(self.channel);
                    conn.flush();
                }
            }
        }
    }
}

impl Drop for MuxTransport {
    fn drop(&mut self) {
        self.close();
    }
}

impl Transport for MuxTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&mut self, to: usize, frame: &[u8]) -> Result<(), NetError> {
        // One deadline for the whole logical op: a peer that keeps the
        // queue full (or keeps accepting bytes slowly) still times out.
        let deadline = Instant::now() + self.timeout;
        let mut spins = 0u32;
        loop {
            if self.try_send(to, frame)? {
                return Ok(());
            }
            if self.aborted() {
                return Err(NetError::Aborted { rank: to, round: UNKNOWN_ROUND });
            }
            if Instant::now() > deadline {
                return Err(NetError::Timeout { rank: to, round: UNKNOWN_ROUND });
            }
            self.wait_pass(&mut spins)?;
        }
    }

    fn recv(&mut self, from: usize, out: &mut Vec<u8>) -> Result<(), NetError> {
        assert!(from != self.rank, "rank {} receiving from itself", self.rank);
        let deadline = Instant::now() + self.timeout;
        let mut spins = 0u32;
        loop {
            {
                let mut io = self.lock_io()?;
                let serviced = io.service(Duration::ZERO);
                let Some(conn) = io.conns.get_mut(from).and_then(|c| c.as_mut()) else {
                    return Err(NetError::PeerDead { rank: from, round: UNKNOWN_ROUND });
                };
                if let Some(frame) = conn.take_frame(self.channel) {
                    // Hand the arrival buffer over (Transport allows it).
                    *out = frame;
                    return Ok(());
                }
                serviced?;
                if conn.channel_down(self.channel) {
                    return Err(NetError::PeerDead { rank: from, round: UNKNOWN_ROUND });
                }
            }
            if self.aborted() {
                return Err(NetError::Aborted { rank: from, round: UNKNOWN_ROUND });
            }
            if Instant::now() > deadline {
                return Err(NetError::Timeout { rank: from, round: UNKNOWN_ROUND });
            }
            self.wait_pass(&mut spins)?;
        }
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn set_abort(&mut self, flag: Arc<AtomicBool>) {
        self.abort = Some(flag);
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::tests::exercise_mesh;
    use super::*;

    #[test]
    fn single_channel_mesh_passes_the_conformance_suite() {
        for n in [2, 3, 4] {
            let mesh = MuxTransport::loopback_mesh(n, 1).expect("mesh").remove(0);
            exercise_mesh(mesh);
        }
    }

    #[test]
    fn channels_interleave_without_crosstalk() {
        let mesh = MuxTransport::loopback_mesh(2, 3).expect("mesh");
        let mut handles = Vec::new();
        for (ch, mut endpoints) in mesh.into_iter().enumerate() {
            let mut r1 = endpoints.remove(1);
            let mut r0 = endpoints.remove(0);
            handles.push(std::thread::spawn(move || {
                for seq in 0..16u8 {
                    let payload = [ch as u8, seq, 0x5A];
                    r0.send(1, &payload).unwrap();
                    let mut out = Vec::new();
                    r1.recv(0, &mut out).unwrap();
                    assert_eq!(out, payload, "channel {ch} frame {seq}");
                    r1.send(0, &[seq, ch as u8]).unwrap();
                    r0.recv(1, &mut out).unwrap();
                    assert_eq!(out, [seq, ch as u8], "echo on channel {ch}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn full_queue_is_typed_backpressure_then_drains() {
        let mut mesh = MuxTransport::loopback_mesh_with(2, 1, 1).expect("mesh");
        let mut chan = mesh.remove(0);
        let mut r1 = chan.remove(1);
        let mut r0 = chan.remove(0);
        // 4 MiB cannot be swallowed by loopback kernel buffers in one
        // write, so the cap-1 queue stays occupied after the first frame.
        let frame = vec![0xCD_u8; 4 << 20];
        let before = m::NET_BACKPRESSURE_EVENTS.get();
        assert!(r0.try_send(1, &frame).unwrap(), "first frame fits the queue");
        assert!(!r0.try_send(1, &frame).unwrap(), "second frame observes backpressure");
        assert!(m::NET_BACKPRESSURE_EVENTS.get() > before, "stall must be counted");
        let want = frame.clone();
        let reader = std::thread::spawn(move || {
            let mut out = Vec::new();
            r1.recv(0, &mut out).unwrap();
            assert_eq!(out, want, "first frame intact");
            r1.recv(0, &mut out).unwrap();
            assert_eq!(out, want, "second frame intact");
        });
        // The blocking send completes once the reader drains the queue.
        r0.send(1, &frame).unwrap();
        reader.join().unwrap();
    }

    #[test]
    fn endpoint_drop_is_a_per_channel_peer_dead() {
        let mut mesh = MuxTransport::loopback_mesh(2, 2).expect("mesh");
        let mut ch1 = mesh.remove(1);
        let mut ch0 = mesh.remove(0);
        let mut a1 = ch0.remove(1);
        let mut a0 = ch0.remove(0);
        let mut b1 = ch1.remove(1);
        let mut b0 = ch1.remove(0);
        a0.send(1, b"bye").unwrap();
        drop(a0);
        let mut out = Vec::new();
        a1.recv(0, &mut out).unwrap();
        assert_eq!(out, b"bye", "frames sent before the close still drain");
        let err = a1.recv(0, &mut out).unwrap_err();
        assert!(err.is_peer_dead(), "{err:?}");
        let err = a1.send(0, b"x").unwrap_err();
        assert!(err.is_peer_dead(), "{err:?}");
        // The sibling channel rides the same sockets, unperturbed.
        b0.send(1, b"alive").unwrap();
        b1.recv(0, &mut out).unwrap();
        assert_eq!(out, b"alive");
        b1.send(0, b"back").unwrap();
        b0.recv(1, &mut out).unwrap();
        assert_eq!(out, b"back");
    }

    #[test]
    fn recv_deadline_is_a_typed_timeout() {
        let mut mesh = MuxTransport::loopback_mesh(2, 1).expect("mesh");
        let mut chan = mesh.remove(0);
        let mut r1 = chan.remove(1);
        r1.set_timeout(Duration::from_millis(40));
        let mut out = Vec::new();
        let err = r1.recv(0, &mut out).unwrap_err();
        assert!(matches!(err, NetError::Timeout { rank: 0, .. }), "{err:?}");
    }

    #[test]
    fn abort_flag_ends_a_blocked_recv() {
        let mut mesh = MuxTransport::loopback_mesh(2, 1).expect("mesh");
        let mut chan = mesh.remove(0);
        let mut r1 = chan.remove(1);
        let flag = Arc::new(AtomicBool::new(false));
        r1.set_abort(Arc::clone(&flag));
        r1.set_timeout(Duration::from_secs(30));
        let armed = Arc::clone(&flag);
        let arm = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            armed.store(true, Ordering::Relaxed);
        });
        let mut out = Vec::new();
        let err = r1.recv(0, &mut out).unwrap_err();
        assert!(matches!(err, NetError::Aborted { .. }), "{err:?}");
        arm.join().unwrap();
    }
}
