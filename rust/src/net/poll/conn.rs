//! Per-connection state for the mux event loop: one nonblocking
//! `TcpStream` per rank pair carries every logical channel's frames as
//! interleaved envelopes, with single-cursor reassembly on the read
//! side and one batched staging buffer on the write side.
//!
//! **Envelope format** (transport framing, invisible to `net::frame`):
//! `[u32 LE body_len][u32 LE channel_word][body bytes]`. The body is a
//! complete message frame, bit-identical to what `TcpTransport` would
//! carry, which is what keeps single-job mux runs bitwise equal to the
//! dedicated-socket path. `channel_word`'s top bit ([`CLOSE_FLAG`])
//! marks a zero-body control envelope announcing that the sender's
//! endpoint for that channel is gone for good — the mux equivalent of
//! a per-channel EOF, so one job's dead rank reads as `PeerDead` on its
//! own channel while every other job's traffic keeps flowing over the
//! same socket.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

use super::super::{NetError, UNKNOWN_ROUND};
use crate::net::tcp::MAX_FRAME_BYTES;
use crate::util::cast;

/// Bytes of envelope header preceding every body.
pub(crate) const ENVELOPE_BYTES: usize = 8;
/// Top bit of `channel_word`: a zero-body per-channel close control.
pub(crate) const CLOSE_FLAG: u32 = 0x8000_0000;

fn fatal_kind(kind: ErrorKind) -> bool {
    matches!(
        kind,
        ErrorKind::ConnectionReset
            | ErrorKind::ConnectionAborted
            | ErrorKind::BrokenPipe
            | ErrorKind::UnexpectedEof
    )
}

/// One multiplexed rank-pair connection.
pub(crate) struct Conn {
    stream: TcpStream,
    /// Raw inbound bytes, possibly ending mid-envelope.
    rbuf: Vec<u8>,
    /// Complete demuxed frames per channel, in arrival order.
    inboxes: Vec<VecDeque<Vec<u8>>>,
    /// Channels whose peer endpoint announced close ([`CLOSE_FLAG`]).
    peer_closed: Vec<bool>,
    /// Staged outbound bytes — every queued envelope, all channels, in
    /// enqueue order; flushed with one `write` per event-loop pass so
    /// concurrent jobs' frames batch into shared syscalls.
    wbuf: Vec<u8>,
    /// Bytes of `wbuf` the kernel has already accepted.
    wstart: usize,
    /// Envelope boundaries still in flight: (end offset in `wbuf`,
    /// channel_word) — drives the per-channel pending accounting.
    inflight: VecDeque<(usize, u32)>,
    /// Frames enqueued but not yet fully written, per channel: the
    /// bounded-queue account behind send backpressure.
    pending: Vec<usize>,
    /// Connection-level EOF or fatal/poisoning IO error seen.
    pub(crate) closed: bool,
}

impl Conn {
    // intlint: allow(R2, reason="mesh construction runs once per process, off the round path")
    pub(crate) fn new(stream: TcpStream, channels: usize) -> Result<Conn> {
        stream.set_nodelay(true).context("set_nodelay")?;
        stream.set_nonblocking(true).context("set_nonblocking")?;
        Ok(Conn {
            stream,
            rbuf: Vec::new(),
            inboxes: (0..channels).map(|_| VecDeque::new()).collect(),
            peer_closed: vec![false; channels],
            wbuf: Vec::new(),
            wstart: 0,
            inflight: VecDeque::new(),
            pending: vec![0; channels],
            closed: false,
        })
    }

    /// Raw descriptor for the poll set.
    #[cfg(target_os = "linux")]
    pub(crate) fn raw_fd(&self) -> i32 {
        use std::os::unix::io::AsRawFd;
        self.stream.as_raw_fd()
    }

    #[cfg(not(target_os = "linux"))]
    pub(crate) fn raw_fd(&self) -> i32 {
        -1
    }

    /// Bytes staged but not yet accepted by the kernel.
    pub(crate) fn wants_write(&self) -> bool {
        self.wstart < self.wbuf.len()
    }

    /// Frames queued-but-unwritten on `channel`.
    pub(crate) fn pending(&self, channel: usize) -> usize {
        self.pending.get(channel).copied().unwrap_or(0)
    }

    /// True once this connection (or this channel's peer endpoint) can
    /// never deliver again: frames may still be queued in the inbox.
    pub(crate) fn channel_down(&self, channel: usize) -> bool {
        self.closed || self.peer_closed.get(channel).copied().unwrap_or(true)
    }

    /// Pop the next complete frame for `channel`, if any.
    pub(crate) fn take_frame(&mut self, channel: usize) -> Option<Vec<u8>> {
        self.inboxes.get_mut(channel).and_then(|q| q.pop_front())
    }

    /// Stage one frame for `channel`. The caller enforces the bounded
    /// queue (checks [`Conn::pending`] against the cap first) and the
    /// [`MAX_FRAME_BYTES`] body cap.
    pub(crate) fn enqueue(&mut self, channel: usize, body: &[u8]) {
        debug_assert!(body.len() <= MAX_FRAME_BYTES);
        let word = cast::sat_u32(channel);
        self.wbuf.extend_from_slice(&cast::sat_u32(body.len()).to_le_bytes());
        self.wbuf.extend_from_slice(&word.to_le_bytes());
        self.wbuf.extend_from_slice(body);
        self.inflight.push_back((self.wbuf.len(), word));
        if let Some(p) = self.pending.get_mut(channel) {
            *p += 1;
        }
    }

    /// Stage the zero-body close control for `channel` (bypasses the
    /// bounded queue: controls must go out even under backpressure).
    pub(crate) fn enqueue_close(&mut self, channel: usize) {
        let word = cast::sat_u32(channel) | CLOSE_FLAG;
        self.wbuf.extend_from_slice(&0u32.to_le_bytes());
        self.wbuf.extend_from_slice(&word.to_le_bytes());
        self.inflight.push_back((self.wbuf.len(), word));
    }

    /// One nonblocking write pass over the staged buffer. All failures
    /// poison the connection (`closed`) rather than erroring, so a
    /// collateral flush on behalf of an unrelated channel never surfaces
    /// another job's broken peer; the owning channel observes the
    /// condition as `PeerDead` via [`Conn::channel_down`]. Returns
    /// whether any bytes moved.
    pub(crate) fn flush(&mut self) -> bool {
        if self.closed {
            return false;
        }
        let mut progressed = false;
        while self.wstart < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wstart..]) {
                Ok(0) => {
                    self.closed = true;
                    break;
                }
                Ok(k) => {
                    self.wstart += k;
                    progressed = true;
                    while let Some(&(end, word)) = self.inflight.front() {
                        if end > self.wstart {
                            break;
                        }
                        self.inflight.pop_front();
                        if word & CLOSE_FLAG == 0 {
                            let ch = cast::usize_from(word);
                            if let Some(p) = self.pending.get_mut(ch) {
                                *p = p.saturating_sub(1);
                            }
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.closed = true;
                    break;
                }
            }
        }
        if self.wstart > 0 && self.wstart == self.wbuf.len() {
            debug_assert!(self.inflight.is_empty());
            self.wbuf.clear();
            self.wstart = 0;
        }
        progressed
    }

    /// Drain whatever the kernel has buffered (one pass of nonblocking
    /// reads), then slice complete envelopes into per-channel inboxes.
    /// `peer_rank` only labels errors. A hostile envelope (oversized
    /// length, unknown channel, non-empty close) poisons the connection
    /// and surfaces `Corrupt` exactly once — after that every channel
    /// reads `PeerDead`, mirroring a torn socket.
    pub(crate) fn pump(&mut self, peer_rank: usize) -> Result<bool, NetError> {
        if self.closed {
            return Ok(false);
        }
        let mut progressed = false;
        let mut chunk = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.closed = true;
                    break;
                }
                Ok(k) => {
                    self.rbuf.extend_from_slice(&chunk[..k]);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if fatal_kind(e.kind()) => {
                    self.closed = true;
                    break;
                }
                Err(e) => {
                    self.closed = true;
                    return Err(NetError::Corrupt {
                        rank: peer_rank,
                        round: UNKNOWN_ROUND,
                        detail: format!("socket read: {e}"),
                    });
                }
            }
        }
        // Slice complete envelopes with one cursor and drain the
        // consumed prefix once at the end (same discipline as
        // tcp::Peer::pump): partially-parsed bytes stay put until the
        // rest of their envelope arrives.
        let mut consumed = 0usize;
        loop {
            let rem = &self.rbuf[consumed..];
            if rem.len() < ENVELOPE_BYTES {
                break;
            }
            let len = cast::usize_from(u32::from_le_bytes([rem[0], rem[1], rem[2], rem[3]]));
            let word = u32::from_le_bytes([rem[4], rem[5], rem[6], rem[7]]);
            let ch = cast::usize_from(word & !CLOSE_FLAG);
            let hostile_close = word & CLOSE_FLAG != 0 && len != 0;
            if len > MAX_FRAME_BYTES || ch >= self.inboxes.len() || hostile_close {
                self.rbuf.drain(..consumed);
                self.closed = true;
                return Err(NetError::Corrupt {
                    rank: peer_rank,
                    round: UNKNOWN_ROUND,
                    detail: format!(
                        "hostile mux envelope: len {len} (cap {MAX_FRAME_BYTES}), \
                         channel {ch} (mesh has {})",
                        self.inboxes.len()
                    ),
                });
            }
            if rem.len() < ENVELOPE_BYTES + len {
                break;
            }
            if word & CLOSE_FLAG != 0 {
                self.peer_closed[ch] = true;
            } else {
                let body = &rem[ENVELOPE_BYTES..ENVELOPE_BYTES + len];
                self.inboxes[ch].push_back(body.to_vec()); // intlint: allow(R2, reason="one owned buffer per arriving frame, handed to recv without a further copy (same cost as the tcp.rs inbox)")
                progressed = true;
            }
            consumed += ENVELOPE_BYTES + len;
        }
        if consumed > 0 {
            self.rbuf.drain(..consumed);
        }
        Ok(progressed)
    }
}
