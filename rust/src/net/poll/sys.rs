//! Thin readiness-polling shim over the platform `poll(2)` syscall.
//!
//! `std::net` owns the sockets but exposes no readiness API, so the mux
//! event loop declares the one libc symbol it needs itself — `std`
//! already links libc on every supported unix target, which keeps the
//! runtime std-only (no new crates). Non-Linux builds fall back to a
//! timed sleep that reports every descriptor ready: callers always
//! follow up with strictly nonblocking IO, so the fallback costs wasted
//! wakeups, never correctness.

use std::time::Duration;

/// Readiness bit: the descriptor has bytes to read (or a pending EOF).
pub const POLLIN: i16 = 0x001;
/// Readiness bit: the descriptor's send buffer can accept bytes.
pub const POLLOUT: i16 = 0x004;

/// One descriptor's poll request/result — the C `struct pollfd` layout.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    /// Raw socket descriptor.
    pub fd: i32,
    /// Requested readiness ([`POLLIN`] | [`POLLOUT`]).
    pub events: i16,
    /// Kernel-reported readiness; error/hangup bits may appear here
    /// unrequested, which callers treat like readiness (the following
    /// nonblocking read/write surfaces the actual condition).
    pub revents: i16,
}

impl PollFd {
    /// A request for `events` on `fd`, `revents` cleared.
    pub fn new(fd: i32, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }
}

/// Block until at least one descriptor in `fds` is ready or `timeout`
/// elapses; returns how many descriptors reported readiness (0 on
/// timeout). EINTR is retried internally so callers never see it.
#[cfg(target_os = "linux")]
pub fn wait(fds: &mut [PollFd], timeout: Duration) -> std::io::Result<usize> {
    use std::ffi::{c_int, c_ulong};
    extern "C" {
        // `poll(2)` — exported by both glibc and musl, which std links.
        fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }
    // The event loop only ever waits in short slices; clamp defensively
    // so a caller-provided Duration can never overflow the C int.
    let ms: c_int = crate::util::cast::to_i32(timeout.as_millis().min(60_000)).unwrap_or(60_000);
    loop {
        // SAFETY: `fds` is an exclusive, live slice of #[repr(C)] PollFd
        // (the C `struct pollfd` layout) and its length is passed
        // alongside the pointer; poll(2) writes only the `revents`
        // fields inside that bound and keeps no reference past the call.
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, ms) };
        if rc >= 0 {
            return Ok(crate::util::cast::to_usize(rc).unwrap_or(0));
        }
        let err = std::io::Error::last_os_error();
        if err.kind() != std::io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// Portable fallback: sleep one short slice, then report everything
/// ready so the caller's nonblocking IO pass makes whatever progress
/// the kernel allows. Busy-ish, but bounded by the slice length.
#[cfg(not(target_os = "linux"))]
pub fn wait(fds: &mut [PollFd], timeout: Duration) -> std::io::Result<usize> {
    if !timeout.is_zero() {
        std::thread::sleep(timeout.min(Duration::from_millis(1)));
    }
    for fd in fds.iter_mut() {
        fd.revents = fd.events;
    }
    Ok(fds.len())
}

#[cfg(test)]
// Wall-clock reads here only time the poll wait itself (clippy.toml's
// net-deadline allowed zone).
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    #[cfg(unix)]
    use std::os::unix::io::AsRawFd;

    #[cfg(target_os = "linux")]
    fn pair() -> (std::net::TcpStream, std::net::TcpStream) {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = std::net::TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn connected_socket_is_writable_and_becomes_readable() {
        let (mut a, b) = pair();
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN | POLLOUT)];
        let n = wait(&mut fds, Duration::from_millis(200)).unwrap();
        assert_eq!(n, 1, "a fresh socket should be writable");
        assert_ne!(fds[0].revents & POLLOUT, 0);
        assert_eq!(fds[0].revents & POLLIN, 0, "nothing sent yet");

        a.write_all(b"ping").unwrap();
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        let n = wait(&mut fds, Duration::from_millis(2000)).unwrap();
        assert_eq!(n, 1);
        assert_ne!(fds[0].revents & POLLIN, 0);
        let mut buf = [0u8; 4];
        let mut b = b;
        b.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn idle_socket_times_out_with_zero_ready() {
        let (_a, b) = pair();
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        let t0 = std::time::Instant::now();
        let n = wait(&mut fds, Duration::from_millis(20)).unwrap();
        assert_eq!(n, 0);
        assert!(t0.elapsed() >= Duration::from_millis(15), "must actually sleep");
    }
}
