//! `net::poll` — the nonblocking multiplexed runtime (DESIGN.md §13).
//!
//! Three layers, bottom up:
//!
//! * [`sys`] — a std-only readiness shim over the `poll(2)` syscall
//!   (no new crates; non-Linux builds degrade to a timed sleep).
//! * [`conn`] (private) — per-rank-pair connection state: envelope
//!   reassembly on the read side, one batched staging buffer plus
//!   bounded per-channel queues on the write side.
//! * [`mux`] — [`MuxTransport`], one (rank, channel) endpoint over a
//!   shared per-rank event-loop core; a drop-in [`crate::net::Transport`]
//!   backend, so `TransportReducer` and every staged collective run on
//!   it unchanged while many logical channels (= concurrent jobs)
//!   interleave over one socket mesh.
//!
//! Isolation story: the channel id is transport framing, checked and
//! stripped before a frame reaches a channel's inbox, so the existing
//! round-id/seq frame guard keeps operating per job exactly as it does
//! on dedicated sockets — cross-job frames cannot reach a job's guard
//! in the first place.

pub mod sys;

pub(crate) mod conn;

pub mod mux;

pub use mux::{MuxTransport, DEFAULT_QUEUE_FRAMES, MAX_CHANNELS};
