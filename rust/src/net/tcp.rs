//! Loopback TCP transport: `std::net` sockets, length-prefixed frames,
//! no crates beyond std.
//!
//! **Mesh setup.** `loopback_mesh(n)` binds one ephemeral listener per
//! rank, then connects every ordered pair `i < j` (rank i dials rank j's
//! listener). Loopback connects complete into the listen backlog without
//! an `accept`, so the whole mesh is built single-threaded; a 4-byte
//! hello carrying the dialer's rank lets the acceptor attribute each
//! inbound stream to its peer. Streams are full-duplex: the pair (i, j)
//! shares one TCP connection, each side holding its own handle.
//!
//! **Stream framing.** Each message is `[u32 LE length][frame bytes]`
//! (the frame bytes being `net::frame`'s header + payload — the length
//! prefix is transport framing, absent on the message-oriented channel
//! transport).
//!
//! **Deadlock freedom.** Kernel socket buffers are finite, and a staged
//! collective has every rank sending before it receives: if `send`
//! blocked in `write` while every peer also blocked in `write`, nobody
//! would drain and the mesh would wedge. All streams therefore run
//! nonblocking; whenever a write hits `WouldBlock`, the transport first
//! **pumps** — drains every peer's inbound bytes into per-peer frame
//! inboxes — before retrying. A rank applying backpressure is thus always
//! also consuming, so some write in the mesh can always complete. `recv`
//! pumps the same way while waiting, serving frames from the requested
//! peer's inbox in arrival order and leaving other peers' frames queued.
//!
//! **Failure semantics** ([`super::NetError`]): EOF / reset / a closed
//! connection is [`NetError::PeerDead`] (how a killed rank looks from the
//! outside); a hostile length prefix is [`NetError::Corrupt`]; a deadline
//! expiry ([`super::Transport::set_timeout`], env `INTSGD_NET_TIMEOUT_MS`,
//! default 30 s) is [`NetError::Timeout`]; a raised abort flag ends the
//! blocking loop as [`NetError::Aborted`] so one rank's failure does not
//! cost the survivors a full timeout.

// Transport deadline/timeout machinery is an allowed zone for
// wall-clock reads (clippy.toml): socket deadlines are wall time by
// nature and never feed round arithmetic.
#![allow(clippy::disallowed_methods)]

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::{default_io_timeout, NetError, Transport, UNKNOWN_ROUND};
use crate::util::cast;

/// Upper bound on one frame's length prefix — a corrupt prefix must
/// produce an error, not a multi-gigabyte allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 26; // 64 MiB

/// After this many fruitless nonblocking spins, start yielding the CPU
/// between polls (latency-first at the start, cores-first when idle).
const SPIN_BEFORE_YIELD: u32 = 128;

struct Peer {
    stream: TcpStream,
    /// Raw inbound bytes, possibly ending mid-frame.
    rbuf: Vec<u8>,
    /// Complete frames, in arrival order.
    inbox: VecDeque<Vec<u8>>,
    /// Peer closed its end (EOF seen).
    closed: bool,
}

fn io_error(peer: usize, what: &str, e: std::io::Error) -> NetError {
    match e.kind() {
        ErrorKind::ConnectionReset
        | ErrorKind::ConnectionAborted
        | ErrorKind::BrokenPipe
        | ErrorKind::UnexpectedEof => NetError::PeerDead { rank: peer, round: UNKNOWN_ROUND },
        _ => NetError::Corrupt {
            rank: peer,
            round: UNKNOWN_ROUND,
            detail: format!("socket {what}: {e}"),
        },
    }
}

impl Peer {
    fn new(stream: TcpStream) -> Result<Peer> {
        stream.set_nodelay(true).context("set_nodelay")?;
        stream.set_nonblocking(true).context("set_nonblocking")?;
        Ok(Peer { stream, rbuf: Vec::new(), inbox: VecDeque::new(), closed: false })
    }

    /// Drain whatever the kernel has buffered for this peer (one pass of
    /// nonblocking reads), slicing complete frames into the inbox.
    /// `peer_rank` only labels errors.
    fn pump(&mut self, peer_rank: usize) -> Result<(), NetError> {
        if self.closed {
            return Ok(());
        }
        let mut chunk = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.closed = true;
                    break;
                }
                Ok(k) => {
                    self.rbuf.extend_from_slice(&chunk[..k]);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                // A connection-fatal error (RST from a killed peer) is
                // this stream's EOF, not the caller's problem: pumping is
                // collateral draining, and failing an *unrelated*
                // send/recv here would keep re-failing the survivors long
                // after the dead rank left the world. Mark the peer
                // closed; operations that address IT get PeerDead.
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::ConnectionReset
                            | ErrorKind::ConnectionAborted
                            | ErrorKind::BrokenPipe
                            | ErrorKind::UnexpectedEof
                    ) =>
                {
                    self.closed = true;
                    break;
                }
                Err(e) => return Err(io_error(peer_rank, "read", e)),
            }
        }
        // Slice complete frames off with a cursor and drain the consumed
        // prefix once — a per-frame drain would memmove the whole tail
        // for every frame, O(frames x buffered bytes) on the very path
        // the transport benchmark measures.
        let mut consumed = 0usize;
        let mut bad_prefix = None;
        loop {
            let rem = &self.rbuf[consumed..];
            if rem.len() < 4 {
                break;
            }
            let len = cast::usize_from(u32::from_le_bytes([rem[0], rem[1], rem[2], rem[3]]));
            if len > MAX_FRAME_BYTES {
                // error AFTER draining what was already sliced: bailing
                // here with the cursor unapplied would re-parse (and
                // duplicate) those frames on the next pump
                bad_prefix = Some(len);
                break;
            }
            if rem.len() < 4 + len {
                break;
            }
            self.inbox.push_back(rem[4..4 + len].to_vec());
            consumed += 4 + len;
        }
        if consumed > 0 {
            self.rbuf.drain(..consumed);
        }
        if let Some(len) = bad_prefix {
            return Err(NetError::Corrupt {
                rank: peer_rank,
                round: UNKNOWN_ROUND,
                detail: format!(
                    "frame length prefix {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
                ),
            });
        }
        Ok(())
    }
}

pub struct TcpTransport {
    rank: usize,
    peers: Vec<Option<Peer>>,
    /// Staging buffer for the length-prefixed write (reused per send).
    wbuf: Vec<u8>,
    /// Give up on a blocked send/recv after this long.
    timeout: Duration,
    abort: Option<Arc<AtomicBool>>,
}

impl TcpTransport {
    /// Build a fully-connected loopback mesh of `n` endpoints on
    /// 127.0.0.1 ephemeral ports; endpoint r is rank r's transport (move
    /// each to its rank's thread).
    pub fn loopback_mesh(n: usize) -> Result<Vec<TcpTransport>> {
        // user-reachable knob (repro net-bench workers=...): clean errors,
        // not asserts
        if n < 1 {
            return Err(anyhow!("at least one rank"));
        }
        if n > 64 {
            return Err(anyhow!(
                "loopback mesh caps at 64 ranks (n^2 sockets; listen backlog), got {n}"
            ));
        }
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0").context("bind loopback listener"))
            .collect::<Result<_>>()?;
        let addrs: Vec<_> = listeners
            .iter()
            .map(|l| l.local_addr().context("listener addr"))
            .collect::<Result<_>>()?;

        let mut peers: Vec<Vec<Option<Peer>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();

        // dial every pair i < j; the connect completes into j's listen
        // backlog, so no concurrent accept loop is needed on loopback
        for i in 0..n {
            for j in i + 1..n {
                let mut stream =
                    TcpStream::connect(addrs[j]).with_context(|| format!("rank {i} -> {j}"))?;
                stream
                    .write_all(&cast::to_u32(i)?.to_le_bytes())
                    .context("send hello")?;
                peers[i][j] = Some(Peer::new(stream)?);
            }
        }
        // accept rank j's inbound streams (one per dialer i < j) and
        // attribute each by its hello
        for (j, listener) in listeners.iter().enumerate() {
            for _ in 0..j {
                let (mut stream, _) = listener.accept().context("accept")?;
                let mut hello = [0u8; 4];
                stream.read_exact(&mut hello).context("read hello")?;
                let i = cast::usize_from(u32::from_le_bytes(hello));
                if i >= n || peers[j][i].is_some() {
                    return Err(anyhow!("bogus hello rank {i} at listener {j}"));
                }
                peers[j][i] = Some(Peer::new(stream)?);
            }
        }
        Ok(peers
            .into_iter()
            .enumerate()
            .map(|(rank, peers)| TcpTransport {
                rank,
                peers,
                wbuf: Vec::new(),
                timeout: default_io_timeout(),
                abort: None,
            })
            .collect())
    }

    /// One nonblocking drain pass over every connected peer — the
    /// progress guarantee both `send` and `recv` lean on.
    fn pump_all(peers: &mut [Option<Peer>]) -> Result<(), NetError> {
        for (rank, peer) in peers.iter_mut().enumerate() {
            if let Some(peer) = peer {
                peer.pump(rank)?;
            }
        }
        Ok(())
    }

    fn backoff(spins: &mut u32) {
        *spins += 1;
        if *spins > SPIN_BEFORE_YIELD {
            std::thread::yield_now();
        }
    }

    fn aborted(&self) -> bool {
        self.abort.as_ref().is_some_and(|f| f.load(Ordering::Relaxed))
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.peers.len()
    }

    fn send(&mut self, to: usize, frame: &[u8]) -> Result<(), NetError> {
        assert!(to != self.rank, "rank {} sending to itself", self.rank);
        if frame.len() > MAX_FRAME_BYTES {
            return Err(NetError::Corrupt {
                rank: to,
                round: UNKNOWN_ROUND,
                detail: format!(
                    "frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte cap",
                    frame.len()
                ),
            });
        }
        let len32 = cast::to_u32(frame.len())
            .map_err(|e| NetError::from_cast(e, to, UNKNOWN_ROUND))?;
        self.wbuf.clear();
        self.wbuf.extend_from_slice(&len32.to_le_bytes());
        self.wbuf.extend_from_slice(frame);
        let deadline = Instant::now() + self.timeout;
        let mut written = 0usize;
        let mut spins = 0u32;
        while written < self.wbuf.len() {
            // The deadline bounds the logical op, not one syscall: check
            // it on every iteration so a slow-but-progressing peer (a
            // few bytes accepted per pass, never a clean WouldBlock)
            // still surfaces a typed timeout (Transport::set_timeout).
            if Instant::now() > deadline {
                return Err(NetError::Timeout { rank: to, round: UNKNOWN_ROUND });
            }
            let peer = self.peers[to]
                .as_mut()
                // intlint: allow(R4, reason="a missing stream is a mesh-construction bug, not a wire-reachable state")
                .unwrap_or_else(|| panic!("no stream to rank {to}"));
            match peer.stream.write(&self.wbuf[written..]) {
                Ok(0) => return Err(NetError::PeerDead { rank: to, round: UNKNOWN_ROUND }),
                Ok(k) => written += k,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    // backpressure: drain inbound so the mesh keeps moving
                    Self::pump_all(&mut self.peers)?;
                    if self.aborted() {
                        return Err(NetError::Aborted { rank: to, round: UNKNOWN_ROUND });
                    }
                    if Instant::now() > deadline {
                        return Err(NetError::Timeout { rank: to, round: UNKNOWN_ROUND });
                    }
                    Self::backoff(&mut spins);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(io_error(to, "write", e)),
            }
        }
        Ok(())
    }

    fn recv(&mut self, from: usize, out: &mut Vec<u8>) -> Result<(), NetError> {
        assert!(from != self.rank, "rank {} receiving from itself", self.rank);
        let deadline = Instant::now() + self.timeout;
        let mut spins = 0u32;
        loop {
            {
                let peer = self.peers[from]
                    .as_mut()
                    // intlint: allow(R4, reason="a missing stream is a mesh-construction bug, not a wire-reachable state")
                    .unwrap_or_else(|| panic!("no stream from rank {from}"));
                if let Some(frame) = peer.inbox.pop_front() {
                    // hand the inbox's buffer over instead of memcpying a
                    // megabyte-scale frame on the measured wire path
                    *out = frame;
                    return Ok(());
                }
                if peer.closed {
                    return Err(NetError::PeerDead { rank: from, round: UNKNOWN_ROUND });
                }
            }
            Self::pump_all(&mut self.peers)?;
            if self.aborted() {
                return Err(NetError::Aborted { rank: from, round: UNKNOWN_ROUND });
            }
            if Instant::now() > deadline {
                return Err(NetError::Timeout { rank: from, round: UNKNOWN_ROUND });
            }
            Self::backoff(&mut spins);
        }
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn set_abort(&mut self, flag: Arc<AtomicBool>) {
        self.abort = Some(flag);
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::exercise_mesh;
    use super::*;

    #[test]
    fn mesh_delivers_ordered_and_isolated() {
        for n in [2usize, 4] {
            exercise_mesh(TcpTransport::loopback_mesh(n).expect("mesh"));
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut mesh = TcpTransport::loopback_mesh(2).unwrap();
        let b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        // write a hostile prefix directly on the raw stream
        let mut raw = &a.peers[1].as_ref().unwrap().stream;
        raw.write_all(&(u32::MAX).to_le_bytes()).unwrap();
        raw.write_all(&[0u8; 8]).unwrap();
        drop(a);
        let mut b = b;
        let err = b.recv(0, &mut Vec::new()).expect_err("cap must trip");
        assert!(matches!(err, NetError::Corrupt { rank: 0, .. }), "{err}");
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn closed_peer_is_peer_dead_instead_of_hanging() {
        let mut mesh = TcpTransport::loopback_mesh(2).unwrap();
        let b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        drop(b);
        let err = a.recv(1, &mut Vec::new()).expect_err("EOF must surface");
        assert!(err.is_peer_dead(), "{err}");
        assert!(err.to_string().contains("closed"), "{err}");
    }

    #[test]
    fn recv_timeout_is_typed_and_configurable() {
        let mut mesh = TcpTransport::loopback_mesh(2).unwrap();
        let _b = mesh.pop().unwrap(); // alive but silent
        let mut a = mesh.pop().unwrap();
        a.set_timeout(Duration::from_millis(40));
        let t0 = Instant::now();
        let err = a.recv(1, &mut Vec::new()).expect_err("deadline must expire");
        assert_eq!(err, NetError::Timeout { rank: 1, round: UNKNOWN_ROUND });
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "stalled rank burned more than the configured timeout"
        );
    }

    #[test]
    fn slow_but_progressing_peer_still_times_out() {
        let mut mesh = TcpTransport::loopback_mesh(2).unwrap();
        let b = mesh.pop().unwrap(); // alive: its kernel socket keeps accepting
        let mut a = mesh.pop().unwrap();
        a.set_timeout(Duration::from_millis(60));
        // Trickle-drain rank 1's end on the raw socket so the sender
        // keeps seeing partial-progress Ok(k) writes instead of a clean
        // WouldBlock; the per-logical-op deadline must still trip.
        let raw = b.peers[0].as_ref().unwrap().stream.try_clone().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let done = Arc::clone(&stop);
        let drain = std::thread::spawn(move || {
            let mut raw = raw;
            let mut sink = [0u8; 1024];
            while !done.load(Ordering::Relaxed) {
                let _ = raw.read(&mut sink); // nonblocking: WouldBlock is fine
                std::thread::sleep(Duration::from_millis(1));
            }
        });
        // 32 MiB cannot drain at ~1 KiB/ms within any plausible socket
        // buffer + 60 ms budget.
        let frame = vec![0u8; 32 << 20];
        let t0 = Instant::now();
        let err = a.send(1, &frame).expect_err("slow progress must still deadline");
        assert_eq!(err, NetError::Timeout { rank: 1, round: UNKNOWN_ROUND });
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "deadline enforcement took far longer than the configured timeout"
        );
        stop.store(true, Ordering::Relaxed);
        drain.join().unwrap();
    }

    #[test]
    fn backpressure_makes_progress_not_deadlock() {
        // Both ranks send a burst far beyond any socket buffer before
        // either receives — exactly the pattern that wedges a blocking
        // mesh. The pump-on-WouldBlock discipline must drain it.
        let mut mesh = TcpTransport::loopback_mesh(2).unwrap();
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        let frame = vec![0x5Au8; 1 << 20]; // 1 MiB per message
        let msgs = 8;
        std::thread::scope(|s| {
            let ha = s.spawn(move || {
                let mut rx = Vec::new();
                for _ in 0..msgs {
                    a.send(1, &frame).unwrap();
                }
                for _ in 0..msgs {
                    a.recv(1, &mut rx).unwrap();
                    assert_eq!(rx.len(), 1 << 20);
                }
            });
            let frame_b = vec![0x5Au8; 1 << 20];
            let hb = s.spawn(move || {
                let mut rx = Vec::new();
                for _ in 0..msgs {
                    b.send(0, &frame_b).unwrap();
                }
                for _ in 0..msgs {
                    b.recv(0, &mut rx).unwrap();
                    assert_eq!(rx.len(), 1 << 20);
                }
            });
            ha.join().unwrap();
            hb.join().unwrap();
        });
    }
}
