//! Frame format: the self-describing header every transported message
//! carries, plus the payload packers the staged collectives use.
//!
//! A frame is `header ++ payload`:
//!
//! ```text
//!   offset  size  field
//!   0       4     round id (u32 LE)   — collective-attempt sequence number
//!   4       4     seq (u32 LE)        — per-(sender, receiver) hop counter
//!                                       within the round
//!   8       1     payload kind        — lane width or opaque codec bytes
//!   9       4     element count (u32) — coordinates (lane kinds) or bytes
//!   13      4     checksum (u32 LE)   — FNV-1a over the payload
//!   17      ...   payload
//! ```
//!
//! The `(round, seq)` pair is the replay guard: the receiving collective
//! knows exactly which frame it awaits from each peer, so a duplicated or
//! reordered frame is a typed [`NetError::Replay`], a frame from an
//! *older* round (a leftover of an aborted attempt, which the
//! `TransportReducer` retries under a fresh round id) is silently skipped
//! ([`check_frame`] → [`FrameCheck::Stale`]), and a frame from a round
//! that has not started yet is rejected.
//!
//! The length prefix that delimits frames on a byte stream is *transport*
//! framing, not message framing — `TcpTransport` adds it, the in-process
//! channel (message-oriented) does not — so the same frame bytes flow over
//! both. Every decode path returns a typed [`NetError`] rather than
//! panicking: these bytes arrive from a socket and must be treated as
//! hostile (`compress::wire` follows the same rule).

use crate::compress::intvec::Lanes;
use crate::util::cast;

use super::{NetError, UNKNOWN_RANK, UNKNOWN_ROUND};

/// Header bytes preceding every payload.
pub const HEADER_BYTES: usize = 17;

fn corrupt(detail: String) -> NetError {
    NetError::Corrupt { rank: UNKNOWN_RANK, round: UNKNOWN_ROUND, detail }
}

fn replay(detail: String) -> NetError {
    NetError::Replay { rank: UNKNOWN_RANK, round: UNKNOWN_ROUND, detail }
}

/// What a frame's payload holds: a lane width for integer partial sums,
/// or opaque codec bytes (sparse / sign / QSGD / NatSGD wire streams,
/// which only the edge decodes). fp32 passes never travel these
/// collectives — exact fp32 folds stay on the leader (DESIGN.md §3) — so
/// there is deliberately no float kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    I8,
    I32,
    I64,
    Bytes,
}

impl PayloadKind {
    pub fn of_lanes(lanes: Lanes) -> PayloadKind {
        match lanes {
            Lanes::I8 => PayloadKind::I8,
            Lanes::I32 => PayloadKind::I32,
            Lanes::I64 => PayloadKind::I64,
        }
    }

    fn tag(self) -> u8 {
        match self {
            PayloadKind::I8 => 0,
            PayloadKind::I32 => 1,
            PayloadKind::I64 => 2,
            PayloadKind::Bytes => 3,
        }
    }

    fn of_tag(tag: u8) -> Result<PayloadKind, NetError> {
        Ok(match tag {
            0 => PayloadKind::I8,
            1 => PayloadKind::I32,
            2 => PayloadKind::I64,
            3 => PayloadKind::Bytes,
            other => return Err(corrupt(format!("unknown payload kind tag {other}"))),
        })
    }

    /// Payload bytes per element (1 for `Bytes`: elements *are* bytes).
    pub fn bytes_per_elem(self) -> usize {
        match self {
            PayloadKind::I8 | PayloadKind::Bytes => 1,
            PayloadKind::I32 => 4,
            PayloadKind::I64 => 8,
        }
    }
}

/// The decoded header of one frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub round: u32,
    /// Hop counter within the round, per ordered (sender, receiver) pair.
    pub seq: u32,
    pub kind: PayloadKind,
    pub elems: u32,
}

/// FNV-1a over the payload: cheap, order-sensitive, and enough to catch
/// the framing bugs a length-prefixed stream can produce (offset slips,
/// truncation, interleaving). Not cryptographic — the threat model is a
/// coding error or an injected fault, not an adversary on loopback.
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= u32::from(b);
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Serialize `header ++ payload` into `out` (cleared first; capacity is
/// reused across rounds).
pub fn encode_frame(header: FrameHeader, payload: &[u8], out: &mut Vec<u8>) {
    debug_assert_eq!(
        payload.len(),
        cast::usize_from(header.elems) * header.kind.bytes_per_elem(),
        "element count disagrees with payload size"
    );
    out.clear();
    out.reserve(HEADER_BYTES + payload.len());
    out.extend_from_slice(&header.round.to_le_bytes());
    out.extend_from_slice(&header.seq.to_le_bytes());
    out.push(header.kind.tag());
    out.extend_from_slice(&header.elems.to_le_bytes());
    out.extend_from_slice(&checksum(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Parse and verify one frame, returning the header and a view of the
/// payload. Rejects short frames, unknown kinds, element counts that
/// disagree with the payload size, and checksum mismatches.
pub fn decode_frame(frame: &[u8]) -> Result<(FrameHeader, &[u8]), NetError> {
    if frame.len() < HEADER_BYTES {
        return Err(corrupt(format!(
            "frame underrun: {} bytes < {HEADER_BYTES}-byte header",
            frame.len()
        )));
    }
    let round = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]);
    let seq = u32::from_le_bytes([frame[4], frame[5], frame[6], frame[7]]);
    let kind = PayloadKind::of_tag(frame[8])?;
    let elems = u32::from_le_bytes([frame[9], frame[10], frame[11], frame[12]]);
    let want_sum = u32::from_le_bytes([frame[13], frame[14], frame[15], frame[16]]);
    let payload = &frame[HEADER_BYTES..];
    let want_len = cast::usize_from(elems) * kind.bytes_per_elem();
    if payload.len() != want_len {
        return Err(corrupt(format!(
            "frame payload {} bytes, header promises {want_len} ({elems} x {kind:?})",
            payload.len()
        )));
    }
    let got_sum = checksum(payload);
    if got_sum != want_sum {
        return Err(corrupt(format!(
            "frame checksum mismatch: payload {got_sum:#010x}, header {want_sum:#010x}"
        )));
    }
    Ok((FrameHeader { round, seq, kind, elems }, payload))
}

/// Bits of the frame sequence number reserved for the pipeline block
/// index (see [`block_seq`]).
pub const BLOCK_SEQ_BITS: u32 = 8;

/// Hop bits left under the block index.
pub const BLOCK_SEQ_SHIFT: u32 = 32 - BLOCK_SEQ_BITS;

/// Compose a frame sequence number from a pipeline block index and the
/// hop counter within that block's collective.
///
/// The streamed round driver runs one staged collective *per gradient
/// block*, with up to two blocks in flight (double buffering). Each
/// per-block collective already gets a fresh attempt round id, but the
/// block index is folded into the seq's high bits as a second guard
/// axis: a frame that strays from one block's schedule into another's
/// can never present a valid `(round, seq)` pair, and the resulting
/// [`NetError::Replay`] names a seq whose high bits identify the block.
/// The index is taken modulo 2^[`BLOCK_SEQ_BITS`] — only the in-flight
/// window (depth 2) must be distinguishable, and 256 blocks is far past
/// any pipeline depth. Hop counters stay well under 2^24 (a hop per
/// schedule step; the longest schedule is the flat ring's 2(n-1) steps).
pub fn block_seq(block: u32, hop: u32) -> u32 {
    debug_assert!(hop < (1 << BLOCK_SEQ_SHIFT), "hop counter {hop} overflows the seq");
    ((block & ((1 << BLOCK_SEQ_BITS) - 1)) << BLOCK_SEQ_SHIFT) | hop
}

/// Verdict of [`check_frame`] on a structurally valid frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameCheck {
    /// The frame the collective awaits — consume its payload.
    Fresh,
    /// A leftover from an aborted earlier attempt (older round id) —
    /// discard it and keep receiving.
    Stale,
}

/// Round-age classification shared by every receive guard: an id
/// strictly behind ours (wrapping distance) is a stale leftover of an
/// aborted attempt, one ahead of ours announces a round this rank never
/// started. One implementation, so the ring all-gather's variable-length
/// guard cannot drift from [`check_frame`] on the wrap boundary.
pub fn classify_round(frame_round: u32, round: u32) -> Result<FrameCheck, NetError> {
    if frame_round == round {
        return Ok(FrameCheck::Fresh);
    }
    let age = round.wrapping_sub(frame_round);
    if age < u32::MAX / 2 {
        return Ok(FrameCheck::Stale);
    }
    Err(replay(format!(
        "frame from future round {frame_round} during round {round}"
    )))
}

/// The per-peer round/sequence guard: validate a received frame against
/// exactly what the collective awaits. Structural damage and shape
/// mismatches are [`NetError::Corrupt`]; a duplicated / reordered /
/// future-round frame is [`NetError::Replay`]; a frame from an *older*
/// round is [`FrameCheck::Stale`] (skip — retried attempts run under a
/// fresh round id and must not trip over the aborted attempt's leftovers).
pub fn check_frame(
    frame: &[u8],
    round: u32,
    seq: u32,
    kind: PayloadKind,
    elems: usize,
) -> Result<FrameCheck, NetError> {
    let (h, _) = decode_frame(frame)?;
    if classify_round(h.round, round)? == FrameCheck::Stale {
        return Ok(FrameCheck::Stale);
    }
    if h.seq != seq {
        let what = if h.seq < seq { "duplicated/replayed" } else { "gap: missing" };
        return Err(replay(format!(
            "{what} frame (seq {}, expected {seq}) in round {round}",
            h.seq
        )));
    }
    if h.kind != kind {
        return Err(corrupt(format!("expected {kind:?} payload, got {:?}", h.kind)));
    }
    if cast::usize_from(h.elems) != elems {
        return Err(corrupt(format!("expected {elems} elements, got {}", h.elems)));
    }
    Ok(FrameCheck::Fresh)
}

/// Expect a frame of exactly this shape (the collectives know the kind,
/// element count, and round of every message they await). Ignores the
/// sequence number — conformance tests and single-shot exchanges use
/// this; the staged collectives go through [`check_frame`].
pub fn expect_frame<'a>(
    frame: &'a [u8],
    round: u32,
    kind: PayloadKind,
    elems: usize,
) -> Result<&'a [u8], NetError> {
    let (h, payload) = decode_frame(frame)?;
    if h.round != round {
        return Err(replay(format!("frame from round {} during round {round}", h.round)));
    }
    if h.kind != kind {
        return Err(corrupt(format!("expected {kind:?} payload, got {:?}", h.kind)));
    }
    if cast::usize_from(h.elems) != elems {
        return Err(corrupt(format!("expected {elems} elements, got {}", h.elems)));
    }
    Ok(payload)
}

/// Pack a range of widened partial sums at the given wire width, with a
/// per-element range check: the caller proves the bound (IntSGD's clip
/// guarantee), the packer refuses to let a violated proof corrupt the
/// stream silently.
pub fn pack_partials(sums: &[i64], wire: Lanes, out: &mut Vec<u8>) -> Result<(), NetError> {
    out.clear();
    out.reserve(sums.len() * wire.bytes());
    match wire {
        Lanes::I8 => {
            for &s in sums {
                let v = i8::try_from(s)
                    .map_err(|_| corrupt(format!("partial sum {s} exceeds the i8 wire")))?;
                out.push(cast::byte_of_i8(v));
            }
        }
        Lanes::I32 => {
            for &s in sums {
                let v = i32::try_from(s).map_err(|_| {
                    corrupt(format!("partial sum {s} exceeds the i32 wire"))
                })?;
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Lanes::I64 => {
            for &s in sums {
                out.extend_from_slice(&s.to_le_bytes());
            }
        }
    }
    Ok(())
}

/// A received i8-lane payload viewed as signed lanes: `u8` and `i8`
/// have identical layout, so the reinterpretation is free and the i8
/// kernels can run straight off the wire bytes.
#[inline]
fn payload_as_i8(payload: &[u8]) -> &[i8] {
    // SAFETY: i8 and u8 have the same size/alignment; any bit pattern
    // is a valid i8.
    unsafe { std::slice::from_raw_parts(payload.as_ptr() as *const i8, payload.len()) }
}

/// Widen a received partial-sum payload and **add** it into `acc`
/// (reduce-scatter's combine step). The i8 arm runs the dispatched
/// widening-add kernel directly on the wire bytes; the wider lanes stay
/// scalar (`from_le_bytes` per element — the payload carries no
/// alignment guarantee).
pub fn add_partials(payload: &[u8], wire: Lanes, acc: &mut [i64]) -> Result<(), NetError> {
    check_payload(payload, wire, acc.len())?;
    match wire {
        Lanes::I8 => crate::simd::add_widen_i8(payload_as_i8(payload), acc),
        Lanes::I32 => {
            for (a, c) in acc.iter_mut().zip(payload.chunks_exact(4)) {
                *a += i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i64;
            }
        }
        Lanes::I64 => {
            for (a, c) in acc.iter_mut().zip(payload.chunks_exact(8)) {
                *a += i64::from_le_bytes([
                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                ]);
            }
        }
    }
    Ok(())
}

/// Widen a received payload of **final** sums and overwrite `dst`
/// (all-gather's distribute step). i8 runs the dispatched widening
/// copy; wider lanes stay scalar (unaligned payload).
pub fn copy_partials(payload: &[u8], wire: Lanes, dst: &mut [i64]) -> Result<(), NetError> {
    check_payload(payload, wire, dst.len())?;
    match wire {
        Lanes::I8 => crate::simd::copy_widen_i8(payload_as_i8(payload), dst),
        Lanes::I32 => {
            for (a, c) in dst.iter_mut().zip(payload.chunks_exact(4)) {
                *a = i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i64;
            }
        }
        Lanes::I64 => {
            for (a, c) in dst.iter_mut().zip(payload.chunks_exact(8)) {
                *a = i64::from_le_bytes([
                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                ]);
            }
        }
    }
    Ok(())
}

fn check_payload(payload: &[u8], wire: Lanes, elems: usize) -> Result<(), NetError> {
    let want = elems * wire.bytes();
    if payload.len() != want {
        return Err(corrupt(format!(
            "payload {} bytes, expected {want} ({elems} x {wire:?})",
            payload.len()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let payload: Vec<u8> = (0..=255u8).collect();
        let h = FrameHeader { round: 7, seq: 3, kind: PayloadKind::Bytes, elems: 256 };
        let mut buf = Vec::new();
        encode_frame(h, &payload, &mut buf);
        assert_eq!(buf.len(), HEADER_BYTES + 256);
        let (back, body) = decode_frame(&buf).unwrap();
        assert_eq!(back, h);
        assert_eq!(body, &payload[..]);
        assert_eq!(expect_frame(&buf, 7, PayloadKind::Bytes, 256).unwrap(), &payload[..]);
        assert_eq!(
            check_frame(&buf, 7, 3, PayloadKind::Bytes, 256).unwrap(),
            FrameCheck::Fresh
        );
    }

    #[test]
    fn corrupt_frames_are_rejected_not_panicked() {
        let payload = [1u8, 2, 3, 4];
        let h = FrameHeader { round: 1, seq: 0, kind: PayloadKind::I32, elems: 1 };
        let mut buf = Vec::new();
        encode_frame(h, &payload, &mut buf);
        // short frame
        assert!(decode_frame(&buf[..HEADER_BYTES - 1]).is_err());
        // flipped payload bit -> checksum mismatch
        let mut bad = buf.clone();
        bad[HEADER_BYTES] ^= 0x40;
        assert!(decode_frame(&bad).unwrap_err().to_string().contains("checksum"));
        // unknown kind tag
        let mut bad = buf.clone();
        bad[8] = 99;
        assert!(decode_frame(&bad).is_err());
        // truncated payload vs promised element count
        let mut bad = buf.clone();
        bad.truncate(HEADER_BYTES + 2);
        assert!(decode_frame(&bad).is_err());
        // wrong expectations
        assert!(expect_frame(&buf, 2, PayloadKind::I32, 1).is_err());
        assert!(expect_frame(&buf, 1, PayloadKind::I8, 4).is_err());
        assert!(expect_frame(&buf, 1, PayloadKind::I32, 2).is_err());
    }

    #[test]
    fn replay_guard_classifies_round_and_seq() {
        let payload = [9u8; 4];
        let mut buf = Vec::new();
        encode_frame(
            FrameHeader { round: 5, seq: 2, kind: PayloadKind::Bytes, elems: 4 },
            &payload,
            &mut buf,
        );
        // exactly what we await
        assert_eq!(
            check_frame(&buf, 5, 2, PayloadKind::Bytes, 4).unwrap(),
            FrameCheck::Fresh
        );
        // older round id: a leftover of an aborted attempt -> skip
        assert_eq!(
            check_frame(&buf, 6, 0, PayloadKind::Bytes, 4).unwrap(),
            FrameCheck::Stale
        );
        // future round id: this rank never started round 5 yet
        let e = check_frame(&buf, 4, 0, PayloadKind::Bytes, 4).unwrap_err();
        assert!(matches!(e, NetError::Replay { .. }), "{e}");
        assert!(e.to_string().contains("future"), "{e}");
        // duplicated frame inside the round (seq already consumed)
        let e = check_frame(&buf, 5, 3, PayloadKind::Bytes, 4).unwrap_err();
        assert!(matches!(e, NetError::Replay { .. }), "{e}");
        assert!(e.to_string().contains("duplicated"), "{e}");
        // a frame from ahead of schedule: the awaited one was lost
        let e = check_frame(&buf, 5, 1, PayloadKind::Bytes, 4).unwrap_err();
        assert!(matches!(e, NetError::Replay { .. }), "{e}");
        assert!(e.to_string().contains("gap"), "{e}");
        // shape mismatches stay Corrupt, not Replay
        let e = check_frame(&buf, 5, 2, PayloadKind::I32, 1).unwrap_err();
        assert!(matches!(e, NetError::Corrupt { .. }), "{e}");
        // round-id wraparound: u32::MAX is "just behind" round 3
        let mut old = Vec::new();
        encode_frame(
            FrameHeader { round: u32::MAX, seq: 0, kind: PayloadKind::Bytes, elems: 4 },
            &payload,
            &mut old,
        );
        assert_eq!(
            check_frame(&old, 3, 0, PayloadKind::Bytes, 4).unwrap(),
            FrameCheck::Stale
        );
    }

    #[test]
    fn partial_pack_widen_roundtrip() {
        use crate::compress::intvec::Lanes;
        let sums = vec![-128i64, -1, 0, 1, 127];
        for wire in [Lanes::I8, Lanes::I32, Lanes::I64] {
            let mut bytes = Vec::new();
            pack_partials(&sums, wire, &mut bytes).unwrap();
            assert_eq!(bytes.len(), sums.len() * wire.bytes());
            let mut acc = vec![10i64; sums.len()];
            add_partials(&bytes, wire, &mut acc).unwrap();
            for (a, &s) in acc.iter().zip(&sums) {
                assert_eq!(*a, 10 + s, "{wire:?}");
            }
            let mut dst = vec![0i64; sums.len()];
            copy_partials(&bytes, wire, &mut dst).unwrap();
            assert_eq!(dst, sums, "{wire:?}");
        }
    }

    #[test]
    fn pack_partials_enforces_the_wire_bound() {
        assert!(pack_partials(&[128], Lanes::I8, &mut Vec::new()).is_err());
        assert!(pack_partials(&[i32::MAX as i64 + 1], Lanes::I32, &mut Vec::new()).is_err());
        assert!(pack_partials(&[i64::MAX], Lanes::I64, &mut Vec::new()).is_ok());
    }

    #[test]
    fn checksum_detects_reorder() {
        assert_ne!(checksum(&[1, 2, 3]), checksum(&[3, 2, 1]));
        assert_ne!(checksum(&[0, 0]), checksum(&[0]));
    }

    #[test]
    fn block_seq_separates_blocks_and_preserves_hops() {
        // block 0 is the plain hop counter (barrier-path frames unchanged)
        assert_eq!(block_seq(0, 0), 0);
        assert_eq!(block_seq(0, 5), 5);
        // hops stay ordered within a block, blocks never collide on seq
        assert!(block_seq(1, 0) > block_seq(0, 1 << 20));
        assert_ne!(block_seq(1, 3), block_seq(2, 3));
        // the index wraps modulo 2^BLOCK_SEQ_BITS (in-flight window is 2)
        assert_eq!(block_seq(256, 7), block_seq(0, 7));
        // a frame carrying a cross-block seq is rejected by the guard
        let payload = [1u8; 4];
        let mut buf = Vec::new();
        encode_frame(
            FrameHeader {
                round: 2,
                seq: block_seq(1, 0),
                kind: PayloadKind::Bytes,
                elems: 4,
            },
            &payload,
            &mut buf,
        );
        let e = check_frame(&buf, 2, block_seq(2, 0), PayloadKind::Bytes, 4).unwrap_err();
        assert!(matches!(e, NetError::Replay { .. }), "{e}");
    }
}
