//! Frame format: the self-describing header every transported message
//! carries, plus the payload packers the staged collectives use.
//!
//! A frame is `header ++ payload`:
//!
//! ```text
//!   offset  size  field
//!   0       4     round id (u32 LE)   — collective-call sequence number
//!   4       1     payload kind        — lane width or opaque codec bytes
//!   5       4     element count (u32) — coordinates (lane kinds) or bytes
//!   9       4     checksum (u32 LE)   — FNV-1a over the payload
//!   13      ...   payload
//! ```
//!
//! The length prefix that delimits frames on a byte stream is *transport*
//! framing, not message framing — `TcpTransport` adds it, the in-process
//! channel (message-oriented) does not — so the same frame bytes flow over
//! both. Every decode path returns `Err` rather than panicking: these
//! bytes arrive from a socket and must be treated as hostile
//! (`compress::wire` follows the same rule).

use anyhow::{anyhow, Result};

use crate::compress::intvec::Lanes;

/// Header bytes preceding every payload.
pub const HEADER_BYTES: usize = 13;

/// What a frame's payload holds: a lane width for integer partial sums,
/// or opaque codec bytes (sparse / sign / QSGD / NatSGD wire streams,
/// which only the edge decodes). fp32 passes never travel these
/// collectives — exact fp32 folds stay on the leader (DESIGN.md §3) — so
/// there is deliberately no float kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    I8,
    I32,
    I64,
    Bytes,
}

impl PayloadKind {
    pub fn of_lanes(lanes: Lanes) -> PayloadKind {
        match lanes {
            Lanes::I8 => PayloadKind::I8,
            Lanes::I32 => PayloadKind::I32,
            Lanes::I64 => PayloadKind::I64,
        }
    }

    fn tag(self) -> u8 {
        match self {
            PayloadKind::I8 => 0,
            PayloadKind::I32 => 1,
            PayloadKind::I64 => 2,
            PayloadKind::Bytes => 3,
        }
    }

    fn of_tag(tag: u8) -> Result<PayloadKind> {
        Ok(match tag {
            0 => PayloadKind::I8,
            1 => PayloadKind::I32,
            2 => PayloadKind::I64,
            3 => PayloadKind::Bytes,
            other => return Err(anyhow!("unknown payload kind tag {other}")),
        })
    }

    /// Payload bytes per element (1 for `Bytes`: elements *are* bytes).
    pub fn bytes_per_elem(self) -> usize {
        match self {
            PayloadKind::I8 | PayloadKind::Bytes => 1,
            PayloadKind::I32 => 4,
            PayloadKind::I64 => 8,
        }
    }
}

/// The decoded header of one frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub round: u32,
    pub kind: PayloadKind,
    pub elems: u32,
}

/// FNV-1a over the payload: cheap, order-sensitive, and enough to catch
/// the framing bugs a length-prefixed stream can produce (offset slips,
/// truncation, interleaving). Not cryptographic — the threat model is a
/// coding error, not an adversary on loopback.
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Serialize `header ++ payload` into `out` (cleared first; capacity is
/// reused across rounds).
pub fn encode_frame(header: FrameHeader, payload: &[u8], out: &mut Vec<u8>) {
    debug_assert_eq!(
        payload.len(),
        header.elems as usize * header.kind.bytes_per_elem(),
        "element count disagrees with payload size"
    );
    out.clear();
    out.reserve(HEADER_BYTES + payload.len());
    out.extend_from_slice(&header.round.to_le_bytes());
    out.push(header.kind.tag());
    out.extend_from_slice(&header.elems.to_le_bytes());
    out.extend_from_slice(&checksum(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Parse and verify one frame, returning the header and a view of the
/// payload. Rejects short frames, unknown kinds, element counts that
/// disagree with the payload size, and checksum mismatches.
pub fn decode_frame(frame: &[u8]) -> Result<(FrameHeader, &[u8])> {
    if frame.len() < HEADER_BYTES {
        return Err(anyhow!(
            "frame underrun: {} bytes < {HEADER_BYTES}-byte header",
            frame.len()
        ));
    }
    let round = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]);
    let kind = PayloadKind::of_tag(frame[4])?;
    let elems = u32::from_le_bytes([frame[5], frame[6], frame[7], frame[8]]);
    let want_sum = u32::from_le_bytes([frame[9], frame[10], frame[11], frame[12]]);
    let payload = &frame[HEADER_BYTES..];
    let want_len = elems as usize * kind.bytes_per_elem();
    if payload.len() != want_len {
        return Err(anyhow!(
            "frame payload {} bytes, header promises {want_len} ({elems} x {kind:?})",
            payload.len()
        ));
    }
    let got_sum = checksum(payload);
    if got_sum != want_sum {
        return Err(anyhow!(
            "frame checksum mismatch: payload {got_sum:#010x}, header {want_sum:#010x}"
        ));
    }
    Ok((FrameHeader { round, kind, elems }, payload))
}

/// Expect a frame of exactly this shape (the collectives know the kind,
/// element count, and round of every message they await).
pub fn expect_frame<'a>(
    frame: &'a [u8],
    round: u32,
    kind: PayloadKind,
    elems: usize,
) -> Result<&'a [u8]> {
    let (h, payload) = decode_frame(frame)?;
    if h.round != round {
        return Err(anyhow!("frame from round {} during round {round}", h.round));
    }
    if h.kind != kind {
        return Err(anyhow!("expected {kind:?} payload, got {:?}", h.kind));
    }
    if h.elems as usize != elems {
        return Err(anyhow!("expected {elems} elements, got {}", h.elems));
    }
    Ok(payload)
}

/// Pack a range of widened partial sums at the given wire width, with a
/// per-element range check: the caller proves the bound (IntSGD's clip
/// guarantee), the packer refuses to let a violated proof corrupt the
/// stream silently.
pub fn pack_partials(sums: &[i64], wire: Lanes, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    out.reserve(sums.len() * wire.bytes());
    match wire {
        Lanes::I8 => {
            for &s in sums {
                let v = i8::try_from(s)
                    .map_err(|_| anyhow!("partial sum {s} exceeds the i8 wire"))?;
                out.push(v as u8);
            }
        }
        Lanes::I32 => {
            for &s in sums {
                let v = i32::try_from(s)
                    .map_err(|_| anyhow!("partial sum {s} exceeds the i32 wire"))?;
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Lanes::I64 => {
            for &s in sums {
                out.extend_from_slice(&s.to_le_bytes());
            }
        }
    }
    Ok(())
}

/// Widen a received partial-sum payload and **add** it into `acc`
/// (reduce-scatter's combine step).
pub fn add_partials(payload: &[u8], wire: Lanes, acc: &mut [i64]) -> Result<()> {
    check_payload(payload, wire, acc.len())?;
    match wire {
        Lanes::I8 => {
            for (a, &b) in acc.iter_mut().zip(payload) {
                *a += (b as i8) as i64;
            }
        }
        Lanes::I32 => {
            for (a, c) in acc.iter_mut().zip(payload.chunks_exact(4)) {
                *a += i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i64;
            }
        }
        Lanes::I64 => {
            for (a, c) in acc.iter_mut().zip(payload.chunks_exact(8)) {
                *a += i64::from_le_bytes([
                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                ]);
            }
        }
    }
    Ok(())
}

/// Widen a received payload of **final** sums and overwrite `dst`
/// (all-gather's distribute step).
pub fn copy_partials(payload: &[u8], wire: Lanes, dst: &mut [i64]) -> Result<()> {
    check_payload(payload, wire, dst.len())?;
    match wire {
        Lanes::I8 => {
            for (a, &b) in dst.iter_mut().zip(payload) {
                *a = (b as i8) as i64;
            }
        }
        Lanes::I32 => {
            for (a, c) in dst.iter_mut().zip(payload.chunks_exact(4)) {
                *a = i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i64;
            }
        }
        Lanes::I64 => {
            for (a, c) in dst.iter_mut().zip(payload.chunks_exact(8)) {
                *a = i64::from_le_bytes([
                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                ]);
            }
        }
    }
    Ok(())
}

fn check_payload(payload: &[u8], wire: Lanes, elems: usize) -> Result<()> {
    let want = elems * wire.bytes();
    if payload.len() != want {
        return Err(anyhow!(
            "payload {} bytes, expected {want} ({elems} x {wire:?})",
            payload.len()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let payload: Vec<u8> = (0..=255u8).collect();
        let h = FrameHeader { round: 7, kind: PayloadKind::Bytes, elems: 256 };
        let mut buf = Vec::new();
        encode_frame(h, &payload, &mut buf);
        assert_eq!(buf.len(), HEADER_BYTES + 256);
        let (back, body) = decode_frame(&buf).unwrap();
        assert_eq!(back, h);
        assert_eq!(body, &payload[..]);
        assert_eq!(expect_frame(&buf, 7, PayloadKind::Bytes, 256).unwrap(), &payload[..]);
    }

    #[test]
    fn corrupt_frames_are_rejected_not_panicked() {
        let payload = [1u8, 2, 3, 4];
        let h = FrameHeader { round: 1, kind: PayloadKind::I32, elems: 1 };
        let mut buf = Vec::new();
        encode_frame(h, &payload, &mut buf);
        // short frame
        assert!(decode_frame(&buf[..HEADER_BYTES - 1]).is_err());
        // flipped payload bit -> checksum mismatch
        let mut bad = buf.clone();
        bad[HEADER_BYTES] ^= 0x40;
        assert!(decode_frame(&bad).unwrap_err().to_string().contains("checksum"));
        // unknown kind tag
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(decode_frame(&bad).is_err());
        // truncated payload vs promised element count
        let mut bad = buf.clone();
        bad.truncate(HEADER_BYTES + 2);
        assert!(decode_frame(&bad).is_err());
        // wrong expectations
        assert!(expect_frame(&buf, 2, PayloadKind::I32, 1).is_err());
        assert!(expect_frame(&buf, 1, PayloadKind::I8, 4).is_err());
        assert!(expect_frame(&buf, 1, PayloadKind::I32, 2).is_err());
    }

    #[test]
    fn partial_pack_widen_roundtrip() {
        use crate::compress::intvec::Lanes;
        let sums = vec![-128i64, -1, 0, 1, 127];
        for wire in [Lanes::I8, Lanes::I32, Lanes::I64] {
            let mut bytes = Vec::new();
            pack_partials(&sums, wire, &mut bytes).unwrap();
            assert_eq!(bytes.len(), sums.len() * wire.bytes());
            let mut acc = vec![10i64; sums.len()];
            add_partials(&bytes, wire, &mut acc).unwrap();
            for (a, &s) in acc.iter().zip(&sums) {
                assert_eq!(*a, 10 + s, "{wire:?}");
            }
            let mut dst = vec![0i64; sums.len()];
            copy_partials(&bytes, wire, &mut dst).unwrap();
            assert_eq!(dst, sums, "{wire:?}");
        }
    }

    #[test]
    fn pack_partials_enforces_the_wire_bound() {
        assert!(pack_partials(&[128], Lanes::I8, &mut Vec::new()).is_err());
        assert!(pack_partials(&[i32::MAX as i64 + 1], Lanes::I32, &mut Vec::new()).is_err());
        assert!(pack_partials(&[i64::MAX], Lanes::I64, &mut Vec::new()).is_ok());
    }

    #[test]
    fn checksum_detects_reorder() {
        assert_ne!(checksum(&[1, 2, 3]), checksum(&[3, 2, 1]));
        assert_ne!(checksum(&[0, 0]), checksum(&[0]));
    }
}
