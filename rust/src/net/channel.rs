//! In-process transport: one unbounded mpsc mailbox per ordered rank
//! pair.
//!
//! This is the tier-1-testable implementation — no sockets, no syscalls,
//! deterministic under `cargo test -q` — and the reference a
//! [`super::TcpTransport`] run must agree with byte for byte (both move
//! the same `frame` bytes; only the delivery mechanism differs). Sends
//! never block (the channel is unbounded), which trivially satisfies the
//! [`super::Transport`] deadlock contract; the per-message `Vec` the
//! channel carries is the price of in-process message passing and is
//! documented as off the zero-alloc hot path (the engine's in-proc
//! reducers remain the allocation-free default).
//!
//! Failure semantics ([`super::NetError`]): a dropped peer transport is
//! [`NetError::PeerDead`] (the channel disconnects — exactly how a killed
//! [`super::FaultTransport`] rank announces itself), an expired deadline
//! is [`NetError::Timeout`], and a raised abort flag
//! ([`super::Transport::set_abort`]) ends a blocked `recv` within one
//! poll slice as [`NetError::Aborted`].

// Transport deadline/timeout machinery is an allowed zone for
// wall-clock reads (clippy.toml): socket deadlines are wall time by
// nature and never feed round arithmetic.
#![allow(clippy::disallowed_methods)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{default_io_timeout, NetError, Transport, UNKNOWN_ROUND};

/// Abort-flag poll slice while blocked in `recv`: the condvar inside
/// `recv_timeout` wakes instantly on arrival, so this bounds only the
/// latency of noticing a peer's failure.
const ABORT_POLL: Duration = Duration::from_millis(2);

pub struct ChannelTransport {
    rank: usize,
    /// `to[j]`: sender delivering into rank j's mailbox from this rank
    /// (`None` at j = rank).
    to: Vec<Option<Sender<Vec<u8>>>>,
    /// `from[i]`: this rank's mailbox for messages sent by rank i
    /// (`None` at i = rank).
    from: Vec<Option<Receiver<Vec<u8>>>>,
    /// Give up on a blocked recv after this long.
    timeout: Duration,
    abort: Option<Arc<AtomicBool>>,
}

impl ChannelTransport {
    /// Build a fully-connected mesh of `n` endpoints; endpoint r is the
    /// transport for rank r (move each to its rank's thread).
    pub fn mesh(n: usize) -> Vec<ChannelTransport> {
        assert!(n >= 1, "at least one rank");
        // pairs[src][dst]: the channel carrying src -> dst messages
        let mut senders: Vec<Vec<Option<Sender<Vec<u8>>>>> = Vec::with_capacity(n);
        let mut receivers: Vec<Vec<Option<Receiver<Vec<u8>>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for src in 0..n {
            let mut row = Vec::with_capacity(n);
            for dst in 0..n {
                if src == dst {
                    row.push(None);
                } else {
                    let (tx, rx) = channel();
                    row.push(Some(tx));
                    receivers[dst][src] = Some(rx);
                }
            }
            senders.push(row);
        }
        senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (to, from))| ChannelTransport {
                rank,
                to,
                from,
                timeout: default_io_timeout(),
                abort: None,
            })
            .collect()
    }

    fn aborted(&self) -> bool {
        self.abort.as_ref().is_some_and(|f| f.load(Ordering::Relaxed))
    }
}

impl Transport for ChannelTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.to.len()
    }

    fn send(&mut self, to: usize, frame: &[u8]) -> Result<(), NetError> {
        let tx = self.to[to]
            .as_ref()
            // intlint: allow(R4, reason="self-send violates the Transport contract; a caller bug, not a wire-reachable state")
            .unwrap_or_else(|| panic!("rank {} sending to itself", self.rank));
        tx.send(frame.to_vec())
            .map_err(|_| NetError::PeerDead { rank: to, round: UNKNOWN_ROUND })
    }

    fn recv(&mut self, from: usize, out: &mut Vec<u8>) -> Result<(), NetError> {
        let rx = self.from[from]
            .as_ref()
            // intlint: allow(R4, reason="self-recv violates the Transport contract; a caller bug, not a wire-reachable state")
            .unwrap_or_else(|| panic!("rank {} receiving from itself", self.rank));
        let deadline = Instant::now() + self.timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left.min(ABORT_POLL)) {
                Ok(msg) => {
                    // hand the message's buffer over rather than copying it
                    *out = msg;
                    return Ok(());
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(NetError::PeerDead { rank: from, round: UNKNOWN_ROUND });
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.aborted() {
                        return Err(NetError::Aborted {
                            rank: from,
                            round: UNKNOWN_ROUND,
                        });
                    }
                    if Instant::now() >= deadline {
                        return Err(NetError::Timeout { rank: from, round: UNKNOWN_ROUND });
                    }
                }
            }
        }
    }

    fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn set_abort(&mut self, flag: Arc<AtomicBool>) {
        self.abort = Some(flag);
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::exercise_mesh;
    use super::*;

    #[test]
    fn mesh_delivers_ordered_and_isolated() {
        for n in [2usize, 3, 5] {
            exercise_mesh(ChannelTransport::mesh(n));
        }
    }

    #[test]
    fn single_rank_mesh_is_valid_but_mute() {
        let mesh = ChannelTransport::mesh(1);
        assert_eq!(mesh[0].world(), 1);
        assert_eq!(mesh[0].rank(), 0);
    }

    #[test]
    fn dropped_peer_is_peer_dead_not_a_hang() {
        let mut mesh = ChannelTransport::mesh(2);
        let b = mesh.pop().unwrap();
        drop(b);
        let a = &mut mesh[0];
        assert!(a.send(1, &[1, 2, 3]).unwrap_err().is_peer_dead());
        let e = a.recv(1, &mut Vec::new()).unwrap_err();
        assert_eq!(e, NetError::PeerDead { rank: 1, round: UNKNOWN_ROUND });
    }

    #[test]
    fn recv_times_out_typed_and_fast() {
        let mut mesh = ChannelTransport::mesh(2);
        let mut a = mesh.remove(0);
        let _b = mesh.remove(0); // alive but silent
        a.set_timeout(Duration::from_millis(30));
        let t0 = Instant::now();
        let e = a.recv(1, &mut Vec::new()).unwrap_err();
        assert_eq!(e, NetError::Timeout { rank: 1, round: UNKNOWN_ROUND });
        assert!(t0.elapsed() < Duration::from_secs(5), "timeout not honored");
    }

    #[test]
    fn abort_flag_ends_a_blocked_recv() {
        let mut mesh = ChannelTransport::mesh(2);
        let mut a = mesh.remove(0);
        let _b = mesh.remove(0);
        let flag = Arc::new(AtomicBool::new(false));
        a.set_abort(Arc::clone(&flag));
        a.set_timeout(Duration::from_secs(30));
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(10));
                flag.store(true, Ordering::Relaxed);
            });
            let t0 = Instant::now();
            let e = a.recv(1, &mut Vec::new()).unwrap_err();
            assert!(matches!(e, NetError::Aborted { rank: 1, .. }), "{e}");
            assert!(t0.elapsed() < Duration::from_secs(5), "abort not honored");
        });
    }
}
