//! In-process transport: one unbounded mpsc mailbox per ordered rank
//! pair.
//!
//! This is the tier-1-testable implementation — no sockets, no syscalls,
//! deterministic under `cargo test -q` — and the reference a
//! [`super::TcpTransport`] run must agree with byte for byte (both move
//! the same `frame` bytes; only the delivery mechanism differs). Sends
//! never block (the channel is unbounded), which trivially satisfies the
//! [`super::Transport`] deadlock contract; the per-message `Vec` the
//! channel carries is the price of in-process message passing and is
//! documented as off the zero-alloc hot path (the engine's in-proc
//! reducers remain the allocation-free default).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::Transport;

/// Give up on a recv after this long: a rank that panicked mid-schedule
/// without dropping its transport must fail the collective, not hang the
/// surviving ranks forever (mirrors `TcpTransport`'s IO timeout).
const RECV_TIMEOUT: Duration = Duration::from_secs(30);

pub struct ChannelTransport {
    rank: usize,
    /// `to[j]`: sender delivering into rank j's mailbox from this rank
    /// (`None` at j = rank).
    to: Vec<Option<Sender<Vec<u8>>>>,
    /// `from[i]`: this rank's mailbox for messages sent by rank i
    /// (`None` at i = rank).
    from: Vec<Option<Receiver<Vec<u8>>>>,
}

impl ChannelTransport {
    /// Build a fully-connected mesh of `n` endpoints; endpoint r is the
    /// transport for rank r (move each to its rank's thread).
    pub fn mesh(n: usize) -> Vec<ChannelTransport> {
        assert!(n >= 1, "at least one rank");
        // pairs[src][dst]: the channel carrying src -> dst messages
        let mut senders: Vec<Vec<Option<Sender<Vec<u8>>>>> = Vec::with_capacity(n);
        let mut receivers: Vec<Vec<Option<Receiver<Vec<u8>>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for src in 0..n {
            let mut row = Vec::with_capacity(n);
            for dst in 0..n {
                if src == dst {
                    row.push(None);
                } else {
                    let (tx, rx) = channel();
                    row.push(Some(tx));
                    receivers[dst][src] = Some(rx);
                }
            }
            senders.push(row);
        }
        senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (to, from))| ChannelTransport { rank, to, from })
            .collect()
    }
}

impl Transport for ChannelTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.to.len()
    }

    fn send(&mut self, to: usize, frame: &[u8]) -> Result<()> {
        let tx = self.to[to]
            .as_ref()
            .unwrap_or_else(|| panic!("rank {} sending to itself", self.rank));
        tx.send(frame.to_vec())
            .map_err(|_| anyhow!("rank {to} hung up (its transport was dropped)"))
    }

    fn recv(&mut self, from: usize, out: &mut Vec<u8>) -> Result<()> {
        let rx = self.from[from]
            .as_ref()
            .unwrap_or_else(|| panic!("rank {} receiving from itself", self.rank));
        let msg = rx.recv_timeout(RECV_TIMEOUT).map_err(|e| match e {
            RecvTimeoutError::Disconnected => {
                anyhow!("rank {from} hung up (its transport was dropped)")
            }
            RecvTimeoutError::Timeout => {
                anyhow!("timed out waiting on a message from rank {from}")
            }
        })?;
        // hand the message's buffer over rather than copying it
        *out = msg;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::exercise_mesh;
    use super::*;

    #[test]
    fn mesh_delivers_ordered_and_isolated() {
        for n in [2usize, 3, 5] {
            exercise_mesh(ChannelTransport::mesh(n));
        }
    }

    #[test]
    fn single_rank_mesh_is_valid_but_mute() {
        let mesh = ChannelTransport::mesh(1);
        assert_eq!(mesh[0].world(), 1);
        assert_eq!(mesh[0].rank(), 0);
    }

    #[test]
    fn dropped_peer_is_an_error_not_a_hang() {
        let mut mesh = ChannelTransport::mesh(2);
        let b = mesh.pop().unwrap();
        drop(b);
        let a = &mut mesh[0];
        assert!(a.send(1, &[1, 2, 3]).is_err());
        assert!(a.recv(1, &mut Vec::new()).is_err());
    }
}
