//! Adaptive scaling-factor rules for IntSGD (paper §4 and Appendix A.1).
//!
//! All rules consume only information every device already has (the model
//! update history and the step size), so every worker derives the *same*
//! alpha_k without extra communication — the property that makes IntSGD
//! all-reduce/INA compatible.

use crate::coordinator::RoundCtx;

/// A rule producing the shared scale alpha_k (or one scale per parameter
/// block for the Alg. 2 variant).
pub trait AlphaRule: Send {
    /// Scalar alpha for the whole gradient.
    fn alpha(&mut self, ctx: &RoundCtx) -> f64;

    /// Per-block alphas written into a reused buffer (default: the scalar
    /// broadcast over all blocks). This is the engine's entry point — it
    /// runs every round, so implementations must not allocate in steady
    /// state.
    fn block_alphas_into(&mut self, ctx: &RoundCtx, out: &mut Vec<f64>) {
        let a = self.alpha(ctx);
        out.clear();
        out.resize(ctx.blocks.len().max(1), a);
    }

    /// Allocating convenience wrapper around [`AlphaRule::block_alphas_into`].
    fn block_alphas(&mut self, ctx: &RoundCtx) -> Vec<f64> {
        let mut out = Vec::new();
        self.block_alphas_into(ctx, &mut out);
        out
    }

    fn name(&self) -> String;
}

/// Paper Alg. 1 / Prop. 2: moving average with safeguard.
///
///   r_k = beta r_{k-1} + (1-beta) ||x^k - x^{k-1}||^2
///   alpha_k = sqrt(d) / sqrt(2 n r_k / eta_k^2 + eps^2)
///
/// Defaults beta = 0.9, eps = 1e-8 (paper §5.1 and Fig. 5).
pub struct MovingAverageRule {
    pub beta: f64,
    pub eps: f64,
    r: f64,
    initialized: bool,
}

impl MovingAverageRule {
    pub fn new(beta: f64, eps: f64) -> Self {
        assert!((0.0..1.0).contains(&beta), "beta must be in [0,1)");
        MovingAverageRule { beta, eps, r: 0.0, initialized: false }
    }

    pub fn default_paper() -> Self {
        Self::new(0.9, 1e-8)
    }
}

impl AlphaRule for MovingAverageRule {
    fn alpha(&mut self, ctx: &RoundCtx) -> f64 {
        // Warm-start the average at the first observed step so early alphas
        // are not dominated by the zero initialisation.
        if !self.initialized {
            self.r = ctx.step_norm_sq;
            self.initialized = true;
        } else {
            self.r = self.beta * self.r + (1.0 - self.beta) * ctx.step_norm_sq;
        }
        let eta = ctx.lr as f64;
        let denom = (2.0 * ctx.n as f64 * self.r / (eta * eta)
            + self.eps * self.eps)
            .sqrt();
        (ctx.d as f64).sqrt() / denom
    }

    fn name(&self) -> String {
        format!("moving_avg(beta={},eps={:.0e})", self.beta, self.eps)
    }
}

/// Appendix Prop. 3: alpha_k = eta_k sqrt(d) / (sqrt(2n) ||x^k - x^{k-1}||),
/// i.e. the moving-average rule with beta = 0, eps = 0. Unsafe when the
/// iterates stall (alpha -> inf); kept for the ablations and IntDIANA.
pub struct Prop3Rule;

impl AlphaRule for Prop3Rule {
    fn alpha(&mut self, ctx: &RoundCtx) -> f64 {
        // alpha = eta * sqrt(d) / (sqrt(2n) * ||x^k - x^{k-1}||)
        let eta = ctx.lr as f64;
        let denom = (2.0 * ctx.n as f64 * ctx.step_norm_sq).sqrt();
        if denom == 0.0 {
            f64::INFINITY
        } else {
            eta * (ctx.d as f64).sqrt() / denom
        }
    }

    fn name(&self) -> String {
        "prop3".into()
    }
}

/// Appendix Prop. 4 / Alg. 2: per-block moving average,
///   alpha_{k,l} = eta_k sqrt(d_l) / sqrt(2 n r_{k,l} + eta_k^2 (d_l/d) eps^2).
pub struct BlockRule {
    pub beta: f64,
    pub eps: f64,
    r: Vec<f64>,
    initialized: bool,
}

impl BlockRule {
    pub fn new(beta: f64, eps: f64) -> Self {
        BlockRule { beta, eps, r: Vec::new(), initialized: false }
    }
}

impl AlphaRule for BlockRule {
    fn alpha(&mut self, ctx: &RoundCtx) -> f64 {
        // Scalar view: weighted combination consistent with Prop. 4's
        // total-error identity; rarely used directly.
        let alphas = self.block_alphas(ctx);
        alphas.iter().copied().fold(f64::INFINITY, f64::min)
    }

    fn block_alphas_into(&mut self, ctx: &RoundCtx, out: &mut Vec<f64>) {
        if self.r.len() != ctx.blocks.len() {
            self.r = vec![0.0; ctx.blocks.len()];
            self.initialized = false;
        }
        if !self.initialized {
            for (r, b) in self.r.iter_mut().zip(&ctx.blocks) {
                *r = b.step_norm_sq;
            }
            self.initialized = true;
        } else {
            for (r, b) in self.r.iter_mut().zip(&ctx.blocks) {
                *r = self.beta * *r + (1.0 - self.beta) * b.step_norm_sq;
            }
        }
        let eta = ctx.lr as f64;
        let d = ctx.d as f64;
        out.clear();
        out.reserve(ctx.blocks.len());
        for (b, &r) in ctx.blocks.iter().zip(&self.r) {
            let dl = b.dim as f64;
            let denom =
                (2.0 * ctx.n as f64 * r + eta * eta * (dl / d) * self.eps * self.eps)
                    .sqrt();
            out.push(if denom == 0.0 {
                f64::INFINITY
            } else {
                eta * dl.sqrt() / denom
            });
        }
    }

    fn name(&self) -> String {
        format!("block(beta={},eps={:.0e})", self.beta, self.eps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BlockInfo;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn ctx(d: usize, n: usize, lr: f32, step_sq: f64) -> RoundCtx {
        RoundCtx {
            round: 1,
            n,
            d,
            lr,
            step_norm_sq: step_sq,
            blocks: vec![BlockInfo { dim: d, step_norm_sq: step_sq }],
        }
    }

    #[test]
    fn moving_avg_matches_closed_form() {
        let mut rule = MovingAverageRule::new(0.0, 1e-8);
        let c = ctx(10_000, 16, 0.1, 0.25);
        let a = rule.alpha(&c);
        let expect = (10_000f64).sqrt()
            / (2.0 * 16.0 * 0.25 / (0.1f64 * 0.1) + 1e-16).sqrt();
        assert!((a - expect).abs() / expect < 1e-6, "{a} vs {expect}"); // f32 lr
    }

    #[test]
    fn safeguard_bounds_alpha_when_steps_vanish() {
        let mut rule = MovingAverageRule::new(0.9, 1e-8);
        let c = ctx(100, 8, 0.1, 0.0);
        let a = rule.alpha(&c);
        assert!(a.is_finite());
        assert!((a - 10.0 / 1e-8).abs() / a < 1e-9); // sqrt(d)/eps
    }

    #[test]
    fn moving_average_decays_towards_new_steps() {
        let mut rule = MovingAverageRule::new(0.9, 0.0);
        let mut a_prev = rule.alpha(&ctx(100, 4, 0.1, 1.0));
        // step norms shrink => alpha should grow monotonically
        for k in 1..20 {
            let a = rule.alpha(&ctx(100, 4, 0.1, 1.0 / (1 << k) as f64));
            assert!(a > a_prev, "alpha should grow as steps shrink");
            a_prev = a;
        }
    }

    #[test]
    fn assumption1_inequality_holds() {
        // Proposition 2: sum_j eta^2/alpha_j^2 == eta^2 eps^2 + 2 n r_k,
        // with r_k the beta-moving average of step norms. We verify the
        // identity (and therefore Assumption 1 with equality) numerically.
        prop_check(0xA55A, 200, |rng| {
            let beta = rng.uniform() * 0.99;
            let eps = 10f64.powf(rng.range(-9.0, -3.0));
            let d = 1 + rng.usize_below(10_000);
            let n = 1 + rng.usize_below(64);
            let mut rule = MovingAverageRule::new(beta, eps);
            let mut r_manual = 0.0;
            let mut first = true;
            for k in 0..10 {
                let step_sq = rng.uniform() * 10.0;
                let lr = 0.01 + rng.uniform_f32();
                let c = ctx(d, n, lr, step_sq);
                let alpha = rule.alpha(&c);
                if first {
                    r_manual = step_sq;
                    first = false;
                } else {
                    r_manual = beta * r_manual + (1.0 - beta) * step_sq;
                }
                let eta = lr as f64;
                let lhs = d as f64 * eta * eta / (alpha * alpha);
                let rhs = eta * eta * eps * eps + 2.0 * n as f64 * r_manual;
                prop_assert!(
                    (lhs - rhs).abs() <= 1e-9 * rhs.max(1e-30),
                    "round {k}: lhs {lhs} rhs {rhs}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn block_rule_reduces_to_scalar_for_single_block() {
        let mut block = BlockRule::new(0.9, 1e-8);
        let c = ctx(5000, 16, 0.05, 0.7);
        let alphas = block.block_alphas(&c);
        assert_eq!(alphas.len(), 1);
        // single block: alpha = eta sqrt(d) / sqrt(2 n r + eta^2 eps^2)
        let eta = 0.05f64;
        let expect = eta * (5000f64).sqrt()
            / (2.0 * 16.0 * 0.7 + eta * eta * 1e-16).sqrt();
        assert!((alphas[0] - expect).abs() / expect < 1e-6); // f32 lr
    }

    #[test]
    fn block_rule_assumption1_identity() {
        // Prop 4: sum_l d_l eta^2 / alpha_l^2 == 2n sum_l r_l + eta^2 eps^2
        // (using the d_l/d safeguard split).
        let mut rule = BlockRule::new(0.0, 1e-6);
        let blocks = vec![
            BlockInfo { dim: 100, step_norm_sq: 0.5 },
            BlockInfo { dim: 300, step_norm_sq: 0.1 },
            BlockInfo { dim: 600, step_norm_sq: 0.0 },
        ];
        let c = RoundCtx {
            round: 1,
            n: 12,
            d: 1000,
            lr: 0.2,
            step_norm_sq: 0.6,
            blocks: blocks.clone(),
        };
        let alphas = rule.block_alphas(&c);
        let eta = 0.2f64;
        let lhs: f64 = blocks
            .iter()
            .zip(&alphas)
            .map(|(b, &a)| b.dim as f64 * eta * eta / (a * a))
            .sum();
        let rhs = 2.0 * 12.0 * 0.6 + eta * eta * 1e-12;
        assert!((lhs - rhs).abs() / rhs < 1e-6, "{lhs} vs {rhs}"); // f32 lr
    }

    #[test]
    fn prop3_unbounded_on_stall() {
        let mut rule = Prop3Rule;
        let a = rule.alpha(&ctx(100, 4, 0.1, 0.0));
        assert!(a.is_infinite());
    }
}
