//! Adaptive scaling-factor rules for IntSGD (paper §4 and Appendix A.1).
//!
//! All rules consume only information every device already has (the model
//! update history and the step size), so every worker derives the *same*
//! alpha_k without extra communication — the property that makes IntSGD
//! all-reduce/INA compatible.

use crate::coordinator::RoundCtx;

/// A rule producing the shared scale alpha_k (or one scale per parameter
/// block for the Alg. 2 variant).
///
/// **Round idempotence.** Stateful rules update their moving averages at
/// most once per `ctx.round`: a second call with the same round (a
/// failover re-plans the round after the world shrank, possibly with a
/// different `ctx.n`) recomputes alpha from the *same* state instead of
/// decaying it twice — otherwise a failed-over run would diverge from a
/// fresh run at the smaller n, which `tests/chaos.rs` pins.
pub trait AlphaRule: Send {
    /// Scalar alpha for the whole gradient.
    fn alpha(&mut self, ctx: &RoundCtx) -> f64;

    /// Serialize the rule's state for checkpoint v2 (None = stateless).
    /// The encoding is rule-private; only [`AlphaRule::import_state`] of
    /// the same rule needs to read it.
    fn export_state(&self) -> Option<Vec<f64>> {
        None
    }

    /// Restore state saved by [`AlphaRule::export_state`].
    fn import_state(&mut self, _state: &[f64]) -> anyhow::Result<()> {
        Err(anyhow::anyhow!("this alpha rule carries no state"))
    }

    /// Per-block alphas written into a reused buffer (default: the scalar
    /// broadcast over all blocks). This is the engine's entry point — it
    /// runs every round, so implementations must not allocate in steady
    /// state.
    fn block_alphas_into(&mut self, ctx: &RoundCtx, out: &mut Vec<f64>) {
        let a = self.alpha(ctx);
        out.clear();
        out.resize(ctx.blocks.len().max(1), a);
    }

    /// Allocating convenience wrapper around [`AlphaRule::block_alphas_into`].
    fn block_alphas(&mut self, ctx: &RoundCtx) -> Vec<f64> {
        let mut out = Vec::new();
        self.block_alphas_into(ctx, &mut out);
        out
    }

    fn name(&self) -> String;
}

/// Paper Alg. 1 / Prop. 2: moving average with safeguard.
///
///   r_k = beta r_{k-1} + (1-beta) ||x^k - x^{k-1}||^2
///   alpha_k = sqrt(d) / sqrt(2 n r_k / eta_k^2 + eps^2)
///
/// Defaults beta = 0.9, eps = 1e-8 (paper §5.1 and Fig. 5).
pub struct MovingAverageRule {
    pub beta: f64,
    pub eps: f64,
    r: f64,
    initialized: bool,
    /// Last round whose step norm was folded into `r` (round idempotence:
    /// a failover re-plan must not decay the average twice).
    last_round: Option<usize>,
}

impl MovingAverageRule {
    pub fn new(beta: f64, eps: f64) -> Self {
        assert!((0.0..1.0).contains(&beta), "beta must be in [0,1)");
        MovingAverageRule { beta, eps, r: 0.0, initialized: false, last_round: None }
    }

    pub fn default_paper() -> Self {
        Self::new(0.9, 1e-8)
    }
}

/// Shared Option<usize> <-> f64 encoding for the rules' checkpoint state
/// (usize rounds are far below 2^53, so the f64 is exact; -1 = None).
fn round_to_f64(r: Option<usize>) -> f64 {
    r.map(|k| k as f64).unwrap_or(-1.0)
}

fn round_from_f64(x: f64) -> Option<usize> {
    (x >= 0.0).then_some(x as usize)
}

impl AlphaRule for MovingAverageRule {
    fn alpha(&mut self, ctx: &RoundCtx) -> f64 {
        // Fold each round's step norm in exactly once; a repeated call
        // for the same round (failover re-plan) reuses the state.
        if self.last_round != Some(ctx.round) {
            // Warm-start the average at the first observed step so early
            // alphas are not dominated by the zero initialisation.
            if !self.initialized {
                self.r = ctx.step_norm_sq;
                self.initialized = true;
            } else {
                self.r = self.beta * self.r + (1.0 - self.beta) * ctx.step_norm_sq;
            }
            self.last_round = Some(ctx.round);
        }
        let eta = ctx.lr as f64;
        let denom = (2.0 * ctx.n as f64 * self.r / (eta * eta)
            + self.eps * self.eps)
            .sqrt();
        (ctx.d as f64).sqrt() / denom
    }

    fn export_state(&self) -> Option<Vec<f64>> {
        Some(vec![
            self.r,
            if self.initialized { 1.0 } else { 0.0 },
            round_to_f64(self.last_round),
        ])
    }

    fn import_state(&mut self, state: &[f64]) -> anyhow::Result<()> {
        if state.len() != 3 {
            anyhow::bail!("moving-average state has {} values, expected 3", state.len());
        }
        self.r = state[0];
        self.initialized = state[1] != 0.0;
        self.last_round = round_from_f64(state[2]);
        Ok(())
    }

    fn name(&self) -> String {
        format!("moving_avg(beta={},eps={:.0e})", self.beta, self.eps)
    }
}

/// Appendix Prop. 3: alpha_k = eta_k sqrt(d) / (sqrt(2n) ||x^k - x^{k-1}||),
/// i.e. the moving-average rule with beta = 0, eps = 0. Unsafe when the
/// iterates stall (alpha -> inf); kept for the ablations and IntDIANA.
pub struct Prop3Rule;

impl AlphaRule for Prop3Rule {
    fn alpha(&mut self, ctx: &RoundCtx) -> f64 {
        // alpha = eta * sqrt(d) / (sqrt(2n) * ||x^k - x^{k-1}||)
        let eta = ctx.lr as f64;
        let denom = (2.0 * ctx.n as f64 * ctx.step_norm_sq).sqrt();
        if denom == 0.0 {
            f64::INFINITY
        } else {
            eta * (ctx.d as f64).sqrt() / denom
        }
    }

    fn name(&self) -> String {
        "prop3".into()
    }
}

/// Appendix Prop. 4 / Alg. 2: per-block moving average,
///   alpha_{k,l} = eta_k sqrt(d_l) / sqrt(2 n r_{k,l} + eta_k^2 (d_l/d) eps^2).
pub struct BlockRule {
    pub beta: f64,
    pub eps: f64,
    r: Vec<f64>,
    initialized: bool,
    /// Round idempotence, as [`MovingAverageRule::last_round`].
    last_round: Option<usize>,
}

impl BlockRule {
    pub fn new(beta: f64, eps: f64) -> Self {
        BlockRule { beta, eps, r: Vec::new(), initialized: false, last_round: None }
    }
}

impl AlphaRule for BlockRule {
    fn alpha(&mut self, ctx: &RoundCtx) -> f64 {
        // Scalar view: weighted combination consistent with Prop. 4's
        // total-error identity; rarely used directly.
        let alphas = self.block_alphas(ctx);
        alphas.iter().copied().fold(f64::INFINITY, f64::min)
    }

    fn export_state(&self) -> Option<Vec<f64>> {
        let mut state = vec![
            if self.initialized { 1.0 } else { 0.0 },
            round_to_f64(self.last_round),
        ];
        state.extend_from_slice(&self.r);
        Some(state)
    }

    fn import_state(&mut self, state: &[f64]) -> anyhow::Result<()> {
        if state.len() < 2 {
            anyhow::bail!("block-rule state has {} values, expected >= 2", state.len());
        }
        self.initialized = state[0] != 0.0;
        self.last_round = round_from_f64(state[1]);
        self.r = state[2..].to_vec();
        Ok(())
    }

    fn block_alphas_into(&mut self, ctx: &RoundCtx, out: &mut Vec<f64>) {
        if self.r.len() != ctx.blocks.len() {
            self.r = vec![0.0; ctx.blocks.len()];
            self.initialized = false;
            self.last_round = None;
        }
        if self.last_round != Some(ctx.round) {
            if !self.initialized {
                for (r, b) in self.r.iter_mut().zip(&ctx.blocks) {
                    *r = b.step_norm_sq;
                }
                self.initialized = true;
            } else {
                for (r, b) in self.r.iter_mut().zip(&ctx.blocks) {
                    *r = self.beta * *r + (1.0 - self.beta) * b.step_norm_sq;
                }
            }
            self.last_round = Some(ctx.round);
        }
        let eta = ctx.lr as f64;
        let d = ctx.d as f64;
        out.clear();
        out.reserve(ctx.blocks.len());
        for (b, &r) in ctx.blocks.iter().zip(&self.r) {
            let dl = b.dim as f64;
            let denom =
                (2.0 * ctx.n as f64 * r + eta * eta * (dl / d) * self.eps * self.eps)
                    .sqrt();
            out.push(if denom == 0.0 {
                f64::INFINITY
            } else {
                eta * dl.sqrt() / denom
            });
        }
    }

    fn name(&self) -> String {
        format!("block(beta={},eps={:.0e})", self.beta, self.eps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BlockInfo;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    fn ctx_at(round: usize, d: usize, n: usize, lr: f32, step_sq: f64) -> RoundCtx {
        RoundCtx {
            round,
            n,
            d,
            lr,
            step_norm_sq: step_sq,
            blocks: vec![BlockInfo { dim: d, step_norm_sq: step_sq }],
        }
    }

    fn ctx(d: usize, n: usize, lr: f32, step_sq: f64) -> RoundCtx {
        ctx_at(1, d, n, lr, step_sq)
    }

    #[test]
    fn moving_avg_matches_closed_form() {
        let mut rule = MovingAverageRule::new(0.0, 1e-8);
        let c = ctx(10_000, 16, 0.1, 0.25);
        let a = rule.alpha(&c);
        let expect = (10_000f64).sqrt()
            / (2.0 * 16.0 * 0.25 / (0.1f64 * 0.1) + 1e-16).sqrt();
        assert!((a - expect).abs() / expect < 1e-6, "{a} vs {expect}"); // f32 lr
    }

    #[test]
    fn safeguard_bounds_alpha_when_steps_vanish() {
        let mut rule = MovingAverageRule::new(0.9, 1e-8);
        let c = ctx(100, 8, 0.1, 0.0);
        let a = rule.alpha(&c);
        assert!(a.is_finite());
        assert!((a - 10.0 / 1e-8).abs() / a < 1e-9); // sqrt(d)/eps
    }

    #[test]
    fn moving_average_decays_towards_new_steps() {
        let mut rule = MovingAverageRule::new(0.9, 0.0);
        let mut a_prev = rule.alpha(&ctx_at(0, 100, 4, 0.1, 1.0));
        // step norms shrink => alpha should grow monotonically
        for k in 1..20 {
            let a = rule.alpha(&ctx_at(k, 100, 4, 0.1, 1.0 / (1 << k) as f64));
            assert!(a > a_prev, "alpha should grow as steps shrink");
            a_prev = a;
        }
    }

    #[test]
    fn replanning_the_same_round_is_idempotent() {
        // A failover re-plans the round after the world shrank: the moving
        // average must fold each round's step in exactly once, and the
        // recomputed alpha must match a fresh rule that saw the same
        // history at the smaller n.
        let mut rule = MovingAverageRule::new(0.9, 1e-8);
        let _ = rule.alpha(&ctx_at(0, 100, 4, 0.1, 0.5));
        let a1 = rule.alpha(&ctx_at(1, 100, 4, 0.1, 0.25));
        // re-plan round 1 at n = 3 (rank died): same r, new n
        let a1_shrunk = rule.alpha(&ctx_at(1, 100, 3, 0.1, 0.25));
        assert_ne!(a1.to_bits(), a1_shrunk.to_bits(), "n must enter the formula");
        // a fresh rule with identical history at n = 3 agrees bit for bit
        let mut fresh = MovingAverageRule::new(0.9, 1e-8);
        let _ = fresh.alpha(&ctx_at(0, 100, 4, 0.1, 0.5));
        let b1 = fresh.alpha(&ctx_at(1, 100, 3, 0.1, 0.25));
        assert_eq!(a1_shrunk.to_bits(), b1.to_bits());
        // and a third call with the same round still does not decay r
        assert_eq!(rule.alpha(&ctx_at(1, 100, 3, 0.1, 0.25)).to_bits(), b1.to_bits());
    }

    #[test]
    fn rule_state_roundtrips_through_export() {
        let mut rule = MovingAverageRule::new(0.9, 1e-8);
        for k in 0..5 {
            let _ = rule.alpha(&ctx_at(k, 64, 4, 0.1, 0.1 * (k + 1) as f64));
        }
        let state = rule.export_state().unwrap();
        let mut back = MovingAverageRule::new(0.9, 1e-8);
        back.import_state(&state).unwrap();
        let a = rule.alpha(&ctx_at(5, 64, 4, 0.1, 0.33));
        let b = back.alpha(&ctx_at(5, 64, 4, 0.1, 0.33));
        assert_eq!(a.to_bits(), b.to_bits());

        let mut block = BlockRule::new(0.9, 1e-8);
        let blocks = vec![
            BlockInfo { dim: 32, step_norm_sq: 0.5 },
            BlockInfo { dim: 32, step_norm_sq: 0.1 },
        ];
        let cx = |round: usize| RoundCtx {
            round,
            n: 4,
            d: 64,
            lr: 0.1,
            step_norm_sq: 0.6,
            blocks: blocks.clone(),
        };
        for k in 0..5 {
            let _ = block.block_alphas(&cx(k));
        }
        let state = block.export_state().unwrap();
        let mut back = BlockRule::new(0.9, 1e-8);
        back.import_state(&state).unwrap();
        assert_eq!(block.block_alphas(&cx(5)), back.block_alphas(&cx(5)));

        // malformed state is a typed error, not garbage
        assert!(back.import_state(&[1.0]).is_err());
        assert!(MovingAverageRule::new(0.9, 1e-8).import_state(&[1.0]).is_err());
        assert!(Prop3Rule.export_state().is_none());
    }

    #[test]
    fn assumption1_inequality_holds() {
        // Proposition 2: sum_j eta^2/alpha_j^2 == eta^2 eps^2 + 2 n r_k,
        // with r_k the beta-moving average of step norms. We verify the
        // identity (and therefore Assumption 1 with equality) numerically.
        prop_check(0xA55A, 200, |rng| {
            let beta = rng.uniform() * 0.99;
            let eps = 10f64.powf(rng.range(-9.0, -3.0));
            let d = 1 + rng.usize_below(10_000);
            let n = 1 + rng.usize_below(64);
            let mut rule = MovingAverageRule::new(beta, eps);
            let mut r_manual = 0.0;
            let mut first = true;
            for k in 0..10 {
                let step_sq = rng.uniform() * 10.0;
                let lr = 0.01 + rng.uniform_f32();
                let c = ctx_at(k, d, n, lr, step_sq);
                let alpha = rule.alpha(&c);
                if first {
                    r_manual = step_sq;
                    first = false;
                } else {
                    r_manual = beta * r_manual + (1.0 - beta) * step_sq;
                }
                let eta = lr as f64;
                let lhs = d as f64 * eta * eta / (alpha * alpha);
                let rhs = eta * eta * eps * eps + 2.0 * n as f64 * r_manual;
                prop_assert!(
                    (lhs - rhs).abs() <= 1e-9 * rhs.max(1e-30),
                    "round {k}: lhs {lhs} rhs {rhs}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn block_rule_reduces_to_scalar_for_single_block() {
        let mut block = BlockRule::new(0.9, 1e-8);
        let c = ctx(5000, 16, 0.05, 0.7);
        let alphas = block.block_alphas(&c);
        assert_eq!(alphas.len(), 1);
        // single block: alpha = eta sqrt(d) / sqrt(2 n r + eta^2 eps^2)
        let eta = 0.05f64;
        let expect = eta * (5000f64).sqrt()
            / (2.0 * 16.0 * 0.7 + eta * eta * 1e-16).sqrt();
        assert!((alphas[0] - expect).abs() / expect < 1e-6); // f32 lr
    }

    #[test]
    fn block_rule_assumption1_identity() {
        // Prop 4: sum_l d_l eta^2 / alpha_l^2 == 2n sum_l r_l + eta^2 eps^2
        // (using the d_l/d safeguard split).
        let mut rule = BlockRule::new(0.0, 1e-6);
        let blocks = vec![
            BlockInfo { dim: 100, step_norm_sq: 0.5 },
            BlockInfo { dim: 300, step_norm_sq: 0.1 },
            BlockInfo { dim: 600, step_norm_sq: 0.0 },
        ];
        let c = RoundCtx {
            round: 1,
            n: 12,
            d: 1000,
            lr: 0.2,
            step_norm_sq: 0.6,
            blocks: blocks.clone(),
        };
        let alphas = rule.block_alphas(&c);
        let eta = 0.2f64;
        let lhs: f64 = blocks
            .iter()
            .zip(&alphas)
            .map(|(b, &a)| b.dim as f64 * eta * eta / (a * a))
            .sum();
        let rhs = 2.0 * 12.0 * 0.6 + eta * eta * 1e-12;
        assert!((lhs - rhs).abs() / rhs < 1e-6, "{lhs} vs {rhs}"); // f32 lr
    }

    #[test]
    fn prop3_unbounded_on_stall() {
        let mut rule = Prop3Rule;
        let a = rule.alpha(&ctx(100, 4, 0.1, 0.0));
        assert!(a.is_infinite());
    }
}
