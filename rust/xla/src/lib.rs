//! Minimal in-tree stand-in for the `xla` (xla_extension 0.5.x) bindings.
//!
//! The offline build environment carries no PJRT runtime, but the L3
//! coordinator only touches a thin slice of the bindings. This crate
//! implements that slice with the same API surface:
//!
//! - [`Literal`] is REAL: host-side tensor plumbing (`vec1`, `reshape`,
//!   `to_vec`, `get_first_element`, `element_count`) works exactly, so
//!   parameter splitting, batch construction, and their tests run.
//! - Everything that would touch a PJRT device ([`PjRtClient::cpu`],
//!   `compile`, `execute`) returns a descriptive [`Error`]. Callers
//!   already treat a failed `Runtime::open` as "artifacts unavailable"
//!   and skip, so the artifact-dependent tests degrade gracefully.
//!
//! To run the on-device path, point the workspace's `xla` dependency at
//! the real xla_extension bindings — no source change needed.

use std::fmt;

/// Binding-level error (mirrors xla_extension's stringly errors).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the PJRT runtime, which this build stubs out \
         (in-tree `xla` stand-in; point the workspace dependency at \
         xla_extension to enable device execution)"
    ))
}

/// Element storage for host literals.
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types the repo moves across the boundary.
pub trait NativeType: Copy + 'static {
    fn wrap(v: Vec<Self>) -> Data;
    fn slice(d: &Data) -> Result<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn slice(d: &Data) -> Result<&[f32]> {
        match d {
            Data::F32(v) => Ok(v),
            _ => Err(Error::new("literal element type is not f32")),
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn slice(d: &Data) -> Result<&[i32]> {
        match d {
            Data::I32(v) => Ok(v),
            _ => Err(Error::new("literal element type is not i32")),
        }
    }
}

/// Host-side tensor: storage + dims. Fully functional.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape to {dims:?} mismatches {} elements",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(T::slice(&self.data)?.to_vec())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::slice(&self.data)?
            .first()
            .copied()
            .ok_or_else(|| Error::new("empty literal"))
    }

    /// Decompose a tuple literal. Only device executions produce tuples,
    /// and the stub cannot execute — unreachable in practice.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module handle (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation handle (opaque in the stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle produced by executions.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn literal_type_checks() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn device_paths_error_descriptively() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("PJRT"));
    }
}
